"""Classical AMG setup: strength of connection, PMIS coarsening, direct
interpolation.  Fully vectorized numpy (no scipy) so the paper-scale problem
(524,288 rows) sets up in seconds on one core.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from ..sparse.csr import CSR


def strength_graph(A: CSR, theta: float = 0.25) -> CSR:
    """Classical strength: j strongly influences i if
    -a_ij >= theta * max_k(-a_ik), k != i.  Returns boolean-pattern CSR
    (data=1.0) without the diagonal."""
    rows = A.row_indices()
    offd = rows != A.indices
    neg = np.where(offd, -A.data, 0.0)
    # per-row max of neg via segment max
    row_max = np.zeros(A.nrows)
    np.maximum.at(row_max, rows, neg)
    keep = offd & (neg >= theta * row_max[rows]) & (neg > 0)
    return CSR.from_coo(
        rows[keep],
        A.indices[keep],
        np.ones(int(keep.sum())),
        A.shape,
    )


def pmis(S: CSR, seed: int = 0) -> np.ndarray:
    """PMIS coarsening on the symmetrized strength graph.

    Returns splitting: +1 C-point, 0 F-point.  Vectorized rounds: a point
    becomes C if its weight beats every undecided strong neighbor; neighbors
    of new C-points become F.
    """
    n = S.nrows
    G = CSR.from_coo(  # symmetrize
        np.concatenate([S.row_indices(), S.indices.astype(np.int64)]),
        np.concatenate([S.indices.astype(np.int64), S.row_indices()]),
        np.ones(2 * S.nnz),
        S.shape,
    )
    rng = np.random.default_rng(seed)
    deg = np.diff(G.indptr).astype(np.float64)
    w = deg + rng.random(n)
    UNDECIDED, CPT, FPT = 0, 1, 2
    state = np.full(n, UNDECIDED, dtype=np.int8)
    state[deg == 0] = FPT  # isolated points need no interpolation
    g_rows = G.row_indices()
    g_cols = G.indices.astype(np.int64)
    while np.any(state == UNDECIDED):
        active_w = np.where(state == UNDECIDED, w, -1.0)
        nbr_max = np.zeros(n)
        edge_active = (state[g_rows] == UNDECIDED)
        np.maximum.at(nbr_max, g_rows[edge_active],
                      active_w[g_cols[edge_active]])
        new_c = (state == UNDECIDED) & (active_w > nbr_max)
        if not np.any(new_c):  # ties (prob ~0): break deterministically
            cand = np.flatnonzero(state == UNDECIDED)
            new_c = np.zeros(n, dtype=bool)
            new_c[cand[0]] = True
        state[new_c] = CPT
        # strong neighbors of new C-points become F
        hit = new_c[g_cols] & (state[g_rows] == UNDECIDED)
        state[g_rows[hit]] = FPT
    return (state == CPT).astype(np.int8)


def direct_interpolation(A: CSR, S: CSR, splitting: np.ndarray) -> CSR:
    """Classical direct interpolation (negative couplings; M-matrix form).

    F-point i interpolates from its strong C-neighbors C_i:
        w_ij = -(sum_k a_ik^-) / (sum_{j in C_i} a_ij^-) * a_ij / a_ii
    F-points with no strong C-neighbor are promoted to C (splitting is
    updated in place).  C-point rows are identity.
    """
    n = A.nrows
    # mark strong edges in A's pattern
    srows, scols = S.row_indices(), S.indices.astype(np.int64)
    strong_lookup = CSR.from_coo(srows, scols, np.ones(len(srows)), A.shape)

    arows = A.row_indices()
    acols = A.indices.astype(np.int64)
    avals = A.data

    # edge is interpolatory: strong and endpoint is C
    # membership test via merged pattern: build keys
    def has_edge(pattern: CSR, r: np.ndarray, c: np.ndarray) -> np.ndarray:
        key_p = pattern.row_indices() * n + pattern.indices.astype(np.int64)
        key_q = r * n + c
        key_p_sorted = np.sort(key_p)
        pos = np.searchsorted(key_p_sorted, key_q)
        pos = np.minimum(pos, len(key_p_sorted) - 1)
        return (len(key_p_sorted) > 0) & (key_p_sorted[pos] == key_q)

    is_strong_edge = has_edge(strong_lookup, arows, acols)

    for _pass in range(30):  # promote until every F has a strong C neighbor
        interp_edge = is_strong_edge & (splitting[acols] == 1)
        has_c = np.zeros(n, dtype=bool)
        has_c[arows[interp_edge]] = True
        bad_f = (splitting == 0) & ~has_c
        # isolated rows (no strong neighbors at all) stay F: they inject 0
        deg_strong = np.zeros(n, dtype=np.int64)
        np.add.at(deg_strong, srows, 1)
        bad_f &= deg_strong > 0
        if not np.any(bad_f):
            break
        splitting = splitting.copy()
        splitting[bad_f] = 1

    cpts = np.flatnonzero(splitting == 1)
    cmap = -np.ones(n, dtype=np.int64)
    cmap[cpts] = np.arange(len(cpts))

    diag = A.diagonal()
    offd = arows != acols
    neg = np.where(offd & (avals < 0), avals, 0.0)
    row_neg_sum = np.zeros(n)
    np.add.at(row_neg_sum, arows, neg)
    interp_edge = is_strong_edge & (splitting[acols] == 1) & (avals < 0)
    row_cneg_sum = np.zeros(n)
    np.add.at(row_cneg_sum, arows[interp_edge], avals[interp_edge])

    fmask = interp_edge & (splitting[arows] == 0)
    ri, ci, vi = arows[fmask], acols[fmask], avals[fmask]
    alpha = np.where(row_cneg_sum[ri] != 0, row_neg_sum[ri] / row_cneg_sum[ri], 0.0)
    w = -alpha * vi / diag[ri]

    prow = np.concatenate([ri, cpts])
    pcol = np.concatenate([cmap[ci], cmap[cpts]])
    pval = np.concatenate([w, np.ones(len(cpts))])
    P = CSR.from_coo(prow, pcol, pval, (n, len(cpts)))
    return P, splitting
