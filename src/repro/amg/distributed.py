"""Device-resident distributed AMG solve on persistent neighborhood collectives.

This closes the loop the paper measures: a BoomerAMG-style V-cycle whose
every SpMV-shaped halo exchange (operator, restriction, prolongation, at
every level) runs through a locality-aware persistent neighborhood
collective — on device, under ``shard_map``, inside one jitted program.

Setup (:meth:`DistributedHierarchy.setup`) is the persistent init: each
hierarchy level is block-partitioned, its communication pattern extracted,
and a ``NeighborAlltoallV`` initialized *once* with the Section-5 dynamic
selector (``strategy="auto"``): communication-light fine levels come out
``standard``, communication-heavy coarse levels aggregated — the paper's
observed optimum.  All plans and bound executors go through a
:class:`~repro.core.cache.PlanCache`, so repeated setups on the same grid
(or operators sharing a pattern) skip re-planning entirely.

Solve: a jitted V-cycle (Chebyshev smoother, degrees matching the host
solver exactly) over ``[P, pad]`` block vectors; matvecs compose the plan
executor with the padded-ELL SpMV kernel (``sparse.device``).  With the
same rho estimates the device residual history tracks the host
:func:`~repro.amg.hierarchy.solve` to rounding error.

Elasticity: :meth:`DistributedHierarchy.repartition` rebuilds the whole
hierarchy onto a different mesh / process count / row balance *through the
same PlanCache*, so only patterns the new geometry has never seen are
re-planned — a grow-back to a previously used geometry re-plans nothing
(observable via the attached ``last_resize`` event).  ``row_weights``
(per-host EWMA step seconds from ``runtime.straggler``) skews every
level's row blocks inversely to measured speed — the straggler mitigation.

Entry points: ``DistributedHierarchy.setup(...)``, ``.solve(b, x0=...)``,
``.repartition(...)``, ``.selection_table()``,
``.measure_exchange_seconds()``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

import numpy as np

from ..core.cache import PlanCache, default_plan_cache
from ..core.costmodel import MachineParams, TPU_V5E, plan_time
from ..core.neighborhood import NeighborAlltoallV
from ..core.plan import Topology
from ..core.selection import SelectionReport
from ..obs import default_obs, now as _now
from ..sparse.device import (
    DEFAULT_BLOCK_COLS,
    DeviceEll,
    DeviceEllBlocked,
    KernelSelection,
    OverlapSelection,
    make_distributed_spmv,
    pack_vector,
    partitioned_to_device,
    select_spmv_kernel,
    select_spmv_overlap,
    unpack_vector,
)
from ..sparse.partition import (
    PartitionedCSR,
    block_offsets,
    partition_rect_csr,
    partitioned_from_blocks,
)
from .distributed_setup import (
    DistributedSetup,
    _block_inv_diag,
    distributed_build_hierarchy,
)
from .hierarchy import Hierarchy, inv_diag

_OBS = default_obs()


@dataclass
class DistOp:
    """One partitioned operator + its persistent collective + device form.

    ``kernel`` records the flat-vs-blocked SpMV choice and ``overlap`` the
    exchange/compute-overlap schedule choice, next to the plan's Section-5
    transport choice, so all three selections travel with the operator.
    """

    part: PartitionedCSR
    coll: NeighborAlltoallV
    ell: "DeviceEll | DeviceEllBlocked"
    kernel: Optional[KernelSelection] = None
    overlap: Optional[OverlapSelection] = None

    @property
    def strategy(self) -> str:
        return self.coll.strategy

    @property
    def selection(self) -> Optional[SelectionReport]:
        return self.coll.selection

    @property
    def kernel_variant(self) -> str:
        return self.kernel.variant if self.kernel else "flat"

    @property
    def overlap_mode(self) -> str:
        return self.overlap.mode if self.overlap else "off"


@dataclass
class DistributedLevel:
    index: int
    n: int                       # global unknowns at this level
    pad: int                     # per-process vector padding
    A: DistOp
    dinv: np.ndarray             # [P, pad] Jacobi scaling (0 in padding)
    rho: float                   # spectral-radius estimate (from host setup)
    R: Optional[DistOp] = None   # fine -> coarse (None on coarsest)
    P: Optional[DistOp] = None   # coarse -> fine


def _default_procs_per_region(n_procs: int) -> int:
    for ppr in (4, 2):
        if n_procs % ppr == 0 and n_procs > ppr:
            return ppr
    return 1


class DistributedHierarchy:
    """A host AMG hierarchy lowered to a device-resident distributed solve."""

    def __init__(
        self,
        levels: List[DistributedLevel],
        mesh,
        axis_name: str,
        topo: Topology,
        cache: PlanCache,
        dtype,
        strategy: str,
        params: MachineParams,
        value_bytes: int,
        spmv_variant: str = "auto",
        spmv_vmem_limit: Optional[int] = None,
        spmv_overlap: str = "auto",
        coarse_gather: str = "off",
    ):
        self.levels = levels
        self.mesh = mesh
        self.axis_name = axis_name
        self.topo = topo
        self.cache = cache
        self.dtype = dtype
        # the cache key under which every collective was initialized —
        # executor lookups must reuse it verbatim to hit the same entries
        self.strategy = strategy
        self.params = params
        self.value_bytes = value_bytes
        # the flat-vs-blocked kernel policy the hierarchy was built under
        self.spmv_variant = spmv_variant
        self.spmv_vmem_limit = spmv_vmem_limit
        # the exchange/compute-overlap policy (auto | on | off)
        self.spmv_overlap = spmv_overlap
        # coarsest-level dense allgatherv policy: "off" keeps the
        # distributed Chebyshev; "auto"/"hier"/"ring" gather the coarse
        # rhs with a plan-based dense collective and smooth replicated
        # (selection recorded in coarse_selection)
        self.coarse_gather = coarse_gather
        self.coarse_selection = None
        # populated by setup_partitioned: the distributed-setup record
        # (per-level blocks + exchange accounting), None for host lowering
        self.setup_info: Optional[DistributedSetup] = None
        # elastic bookkeeping: the host hierarchy this was lowered from
        # (repartition source of truth; reconstructed on demand for
        # setup_partitioned-built hierarchies) and the ResizeEvent of the
        # rebuild that produced this instance (None for a first setup)
        self._host: Optional[Hierarchy] = None
        self.last_resize = None
        self._build_device_fns()

    # ------------------------------------------------------------- setup
    @classmethod
    def setup(
        cls,
        h: Hierarchy,
        mesh,
        axis_name: str = "proc",
        procs_per_region: Optional[int] = None,
        strategy: str = "auto",
        params: MachineParams = TPU_V5E,
        value_bytes: int = 8,
        cache: Optional[PlanCache] = None,
        dtype=np.float64,
        spmv_variant: str = "auto",
        spmv_vmem_limit: Optional[int] = None,
        spmv_block_cols: int = DEFAULT_BLOCK_COLS,
        spmv_overlap: str = "auto",
        coarse_gather: str = "off",
        row_weights: Optional[np.ndarray] = None,
    ) -> "DistributedHierarchy":
        """Partition every level and init its collectives once (persistent).

        ``strategy="auto"`` runs the paper's Section-5 selector per level
        and per transfer operator; pass a concrete strategy to pin it.
        ``spmv_variant="auto"`` likewise selects the flat or column-blocked
        SpMV kernel per operator from its modeled VMEM footprint against
        ``spmv_vmem_limit`` (default: :func:`~repro.sparse.device.
        default_spmv_vmem_limit`, env-overridable); ``"flat"``/``"blocked"``
        pin it.  ``spmv_overlap="auto"`` selects the split
        exchange/compute-overlap schedule per operator whenever the modeled
        hidden exchange time beats the split overhead; ``"on"``/``"off"``
        pin it.  All choices are recorded on each :class:`DistOp`.

        ``row_weights`` (per-host step *seconds*, e.g. the EWMA from
        ``runtime.straggler.StragglerDetector``) skews every level's row
        blocks inversely to the weights via
        ``runtime.straggler.rebalance_shards`` — a 2x-slower host owns half
        the rows.  ``None`` keeps the balanced contiguous blocking.
        """
        n_procs = int(dict(zip(mesh.axis_names, mesh.devices.shape))[axis_name])
        topo = Topology(
            n_procs, procs_per_region or _default_procs_per_region(n_procs)
        )
        cache = cache if cache is not None else default_plan_cache()

        def make_op(mat, row_off, col_off) -> DistOp:
            part = partition_rect_csr(mat, row_off, col_off)
            coll = cache.collective(
                part.pattern, topo, strategy, value_bytes, params
            )
            sel = select_spmv_kernel(
                part, variant=spmv_variant,
                vmem_limit_bytes=spmv_vmem_limit,
                value_bytes=value_bytes, block_cols=spmv_block_cols,
            )
            ell = partitioned_to_device(part, sel, dtype, spmv_block_cols)
            osel = select_spmv_overlap(
                part, plan_time(coll.plan, params),
                mode=spmv_overlap, value_bytes=value_bytes,
            )
            return DistOp(part, coll, ell, sel, osel)

        if row_weights is None:
            offs = [block_offsets(lvl.A.nrows, n_procs) for lvl in h.levels]
        else:
            from ..runtime.straggler import rebalance_shards

            w = np.asarray(row_weights, dtype=float).reshape(-1)
            assert len(w) == n_procs, (len(w), n_procs)
            offs = [
                np.concatenate(
                    [[0], np.cumsum(rebalance_shards(w, lvl.A.nrows))]
                ).astype(np.int64)
                for lvl in h.levels
            ]
        levels: List[DistributedLevel] = []
        with _OBS.span("amg/setup", n_procs=n_procs, strategy=strategy,
                       levels=len(h.levels)):
            for k, lvl in enumerate(h.levels):
                with _OBS.span("amg/build_level", level=k,
                               n=lvl.A.nrows) as lsp:
                    A_op = make_op(lvl.A, offs[k], offs[k])
                    pad = int(np.diff(offs[k]).max())
                    dinv = inv_diag(lvl.A)
                    dl = DistributedLevel(
                        index=k,
                        n=lvl.A.nrows,
                        pad=pad,
                        A=A_op,
                        dinv=pack_vector(offs[k], pad, dinv.astype(dtype)),
                        rho=lvl.rho or 1.0,
                    )
                    if lvl.P is not None and k + 1 < len(h.levels):
                        dl.R = make_op(lvl.R, offs[k + 1], offs[k])
                        dl.P = make_op(lvl.P, offs[k], offs[k + 1])
                    levels.append(dl)
                    lsp.set(strategy=A_op.strategy,
                            kernel=A_op.kernel_variant,
                            overlap=A_op.overlap_mode)
            dh = cls(levels, mesh, axis_name, topo, cache, dtype,
                     strategy, params, value_bytes,
                     spmv_variant=spmv_variant,
                     spmv_vmem_limit=spmv_vmem_limit,
                     spmv_overlap=spmv_overlap,
                     coarse_gather=coarse_gather)
        dh._host = h
        return dh

    @classmethod
    def setup_partitioned(
        cls,
        A_blocks,
        row_offsets: np.ndarray,
        mesh,
        axis_name: str = "proc",
        procs_per_region: Optional[int] = None,
        strategy: str = "auto",
        params: MachineParams = TPU_V5E,
        value_bytes: int = 8,
        cache: Optional[PlanCache] = None,
        dtype=np.float64,
        max_levels: int = 25,
        min_coarse: int = 64,
        strength_theta: float = 0.25,
        seed: int = 0,
        spmv_variant: str = "auto",
        spmv_vmem_limit: Optional[int] = None,
        spmv_block_cols: int = DEFAULT_BLOCK_COLS,
        spmv_overlap: str = "auto",
        coarse_gather: str = "off",
    ) -> "DistributedHierarchy":
        """End-to-end distributed build: partitioned fine matrix -> solve.

        Runs the distributed *setup* (``amg.distributed_setup``: PMIS /
        interpolation / Galerkin SpGEMM over sparse dynamic data exchanges)
        and lowers the resulting per-rank blocks straight to the device
        solve — the global operators are never materialized on one rank.
        Setup and solve share one :class:`PlanCache`; for structurally
        symmetric operators the setup halo pattern IS the solve halo
        pattern, so the solve collectives come out of the cache pre-built.
        """
        n_procs = int(dict(zip(mesh.axis_names, mesh.devices.shape))[axis_name])
        assert n_procs == len(A_blocks), (n_procs, len(A_blocks))
        topo = Topology(
            n_procs, procs_per_region or _default_procs_per_region(n_procs)
        )
        cache = cache if cache is not None else default_plan_cache()
        setup = distributed_build_hierarchy(
            A_blocks, row_offsets, topo, cache=cache,
            max_levels=max_levels, min_coarse=min_coarse,
            strength_theta=strength_theta, seed=seed,
            strategy=strategy, value_bytes=value_bytes, params=params,
        )

        def make_op(blocks, row_off, col_off) -> DistOp:
            part = partitioned_from_blocks(blocks, row_off, col_off)
            coll = cache.collective(
                part.pattern, topo, strategy, value_bytes, params
            )
            sel = select_spmv_kernel(
                part, variant=spmv_variant,
                vmem_limit_bytes=spmv_vmem_limit,
                value_bytes=value_bytes, block_cols=spmv_block_cols,
            )
            ell = partitioned_to_device(part, sel, dtype, spmv_block_cols)
            osel = select_spmv_overlap(
                part, plan_time(coll.plan, params),
                mode=spmv_overlap, value_bytes=value_bytes,
            )
            return DistOp(part, coll, ell, sel, osel)

        levels: List[DistributedLevel] = []
        with _OBS.span("amg/setup_partitioned", n_procs=n_procs,
                       strategy=strategy, levels=len(setup.levels)):
            for k, sl in enumerate(setup.levels):
                with _OBS.span("amg/build_level", level=k,
                               n=sl.nrows) as lsp:
                    A_op = make_op(sl.A_blocks, sl.row_offsets,
                                   sl.row_offsets)
                    pad = int(np.diff(sl.row_offsets).max())
                    dinv = np.zeros((n_procs, pad), dtype=dtype)
                    for p, Ab in enumerate(sl.A_blocks):
                        dinv[p, : Ab.nrows] = _block_inv_diag(
                            Ab, int(sl.row_offsets[p])
                        ).astype(dtype)
                    dl = DistributedLevel(
                        index=k, n=sl.nrows, pad=pad, A=A_op,
                        dinv=dinv, rho=sl.rho or 1.0,
                    )
                    if sl.P_blocks is not None and k + 1 < len(setup.levels):
                        dl.R = make_op(sl.R_blocks, sl.coarse_offsets,
                                       sl.row_offsets)
                        dl.P = make_op(sl.P_blocks, sl.row_offsets,
                                       sl.coarse_offsets)
                    levels.append(dl)
                    lsp.set(strategy=A_op.strategy,
                            kernel=A_op.kernel_variant,
                            overlap=A_op.overlap_mode)
            dh = cls(levels, mesh, axis_name, topo, cache, dtype,
                     strategy, params, value_bytes,
                     spmv_variant=spmv_variant,
                     spmv_vmem_limit=spmv_vmem_limit,
                     spmv_overlap=spmv_overlap,
                     coarse_gather=coarse_gather)
        dh.setup_info = setup
        return dh

    # ------------------------------------------------- device programs
    def _bind(self, op: DistOp) -> Callable:
        exchange = None
        if op.ell.ghost_pad:
            exchange = self._bind_exchange_only(op)
        return make_distributed_spmv(
            op.ell, self.mesh, self.axis_name, exchange,
            overlap=(op.overlap_mode == "on"),
        )

    def _bind_coarse(self) -> Callable:
        """Coarsest-level solve by dense allgatherv + replicated Chebyshev.

        The coarsest packed rhs ``[P, pad]`` is exactly the allgatherv
        input layout (``counts`` = real block sizes, ``cmax`` = pad):
        each device contributes its block, the plan-based gather
        replicates the full coarse vector, and a dense padded coarse
        operator (zeros at padding rows/cols, so no unpadding is needed)
        runs the same degree-24 Chebyshev arithmetic as :meth:`_cheby` —
        every device then keeps its own block of the result.  The
        :class:`~repro.core.dense.DenseSelection` lands in
        :attr:`coarse_selection`.
        """
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as PSpec

        from ..compat import shard_map
        from ..core import dense_round_runner
        from ..sparse.partition import partitioned_to_global

        lv = self.levels[-1]
        offs = np.asarray(lv.A.part.col_offsets, dtype=np.int64)
        counts = np.diff(offs)
        variant = "auto" if self.coarse_gather == "auto" else \
            self.coarse_gather
        plan, sel = self.cache.dense_collective(
            "allgatherv", counts, self.topo, variant=variant,
            value_bytes=self.value_bytes, params=self.params,
        )
        self.coarse_selection = sel
        run = dense_round_runner(plan, self.axis_name)

        P_, pad = self.topo.n_procs, lv.pad
        Ag = partitioned_to_global(lv.A.part)
        # global index -> padded position p*pad + local slot
        pos = np.concatenate([
            p * pad + np.arange(int(counts[p]), dtype=np.int64)
            for p in range(P_)
        ])
        Ad = np.zeros((P_ * pad, P_ * pad), dtype=self.dtype)
        rows = Ag.row_indices().astype(np.int64)
        cols = Ag.indices.astype(np.int64)
        np.add.at(Ad, (pos[rows], pos[cols]), Ag.data.astype(self.dtype))
        Ad_dev = jnp.asarray(Ad)
        dinv = jnp.asarray(np.asarray(lv.dinv).reshape(-1))

        rho = lv.rho
        upper = 1.1 * rho
        lower = 0.30 * rho
        theta = 0.5 * (upper + lower)
        delta = 0.5 * (upper - lower)
        sigma = theta / delta

        def coarse_cheby(b, degree=24):
            x = jnp.zeros_like(b)
            rho_k = 1.0 / sigma
            r = dinv * (b - Ad_dev @ x)
            p = r / theta
            x = x + p
            for _ in range(degree - 1):
                rho_next = 1.0 / (2.0 * sigma - rho_k)
                r = dinv * (b - Ad_dev @ x)
                p = rho_next * rho_k * p + 2.0 * rho_next / delta * r
                x = x + p
                rho_k = rho_next
            return x

        def per_device(b_blk):              # [1, pad] own packed block
            rank = jax.lax.axis_index(self.axis_name)
            zero = jnp.zeros((), rank.dtype)
            buf = jnp.zeros((P_, pad), b_blk.dtype)
            buf = jax.lax.dynamic_update_slice(buf, b_blk, (rank, zero))
            full = run(buf).reshape(-1)     # replicated coarse rhs
            x = coarse_cheby(full).reshape(P_, pad)
            return jax.lax.dynamic_slice(x, (rank, zero), (1, pad))

        spec = PSpec(self.axis_name)
        return shard_map(per_device, mesh=self.mesh, in_specs=(spec,),
                         out_specs=spec, check_rep=False)

    def _build_device_fns(self) -> None:
        import jax

        self._Amv = [self._bind(lv.A) for lv in self.levels]
        self._Rmv = [
            self._bind(lv.R) if lv.R is not None else None
            for lv in self.levels
        ]
        self._Pmv = [
            self._bind(lv.P) if lv.P is not None else None
            for lv in self.levels
        ]
        self._coarse_fn = (
            self._bind_coarse() if self.coarse_gather != "off" else None
        )
        self._step = jax.jit(self._make_step())

    def _cheby(self, k: int, x, b, degree: int):
        """Chebyshev smoother — same arithmetic as the host ``chebyshev``."""
        lv = self.levels[k]
        Amv = self._Amv[k]
        import jax.numpy as jnp

        dinv = jnp.asarray(lv.dinv)
        rho = lv.rho
        upper = 1.1 * rho
        lower = 0.30 * rho
        theta = 0.5 * (upper + lower)
        delta = 0.5 * (upper - lower)
        sigma = theta / delta
        rho_k = 1.0 / sigma
        r = dinv * (b - Amv(x))
        p = r / theta
        x = x + p
        for _ in range(degree - 1):
            rho_next = 1.0 / (2.0 * sigma - rho_k)
            r = dinv * (b - Amv(x))
            p = rho_next * rho_k * p + 2.0 * rho_next / delta * r
            x = x + p
            rho_k = rho_next
        return x

    def _vcycle(self, k: int, b):
        import jax.numpy as jnp

        lv = self.levels[k]
        zero = jnp.zeros_like(b)
        if lv.R is None or k == len(self.levels) - 1:
            if self._coarse_fn is not None:
                return self._coarse_fn(b)
            return self._cheby(k, zero, b, degree=24)
        x = self._cheby(k, zero, b, degree=3)       # pre-smooth
        r = b - self._Amv[k](x)
        rc = self._Rmv[k](r)
        ec = self._vcycle(k + 1, rc)
        x = x + self._Pmv[k](ec)
        return self._cheby(k, x, b, degree=3)       # post-smooth

    def _make_step(self):
        import jax.numpy as jnp

        def step(x, b):
            r = b - self._Amv[0](x)
            rn = jnp.linalg.norm(r)
            return x + self._vcycle(0, r), rn

        return step

    # -------------------------------------------------------------- solve
    def solve(
        self,
        b: np.ndarray,
        tol: float = 1e-8,
        max_iters: int = 100,
        x0: Optional[np.ndarray] = None,
    ) -> Tuple[np.ndarray, List[float]]:
        """AMG-preconditioned stationary iteration, fully on device.

        Mirrors the host :func:`repro.amg.hierarchy.solve` loop (residual
        check before update) so histories are comparable.  ``x0`` (a global
        host vector) warm-starts the iteration — how a solve resumes on a
        repartitioned hierarchy after an elastic resize: the iterate from
        the old geometry is re-packed under the new blocking and the
        contraction continues where it left off.
        """
        import jax.numpy as jnp

        lv0 = self.levels[0]
        bg = jnp.asarray(
            pack_vector(lv0.A.part.col_offsets, lv0.pad, b.astype(self.dtype))
        )
        if x0 is None:
            x = jnp.zeros_like(bg)
        else:
            x = jnp.asarray(
                pack_vector(lv0.A.part.col_offsets, lv0.pad,
                            np.asarray(x0).astype(self.dtype))
            )
        nb = max(float(np.linalg.norm(b)), 1e-300)
        hist: List[float] = []
        with _OBS.span("amg/solve", n=lv0.n, tol=tol,
                       max_iters=max_iters) as sp:
            for it in range(max_iters):
                # the float() is the device sync: the iteration span
                # covers the whole V-cycle, not just its dispatch
                with _OBS.span("amg/vcycle_iter", iter=it):
                    x_new, rn = self._step(x, bg)
                    rel = float(rn) / nb
                hist.append(rel)
                if rel < tol:
                    break
                x = x_new
            sp.set(iters=len(hist), final_rel=hist[-1] if hist else 0.0)
        return unpack_vector(lv0.A.part.offsets, np.asarray(x)), hist

    # ------------------------------------------------------------ elastic
    def _global_hierarchy(self) -> Hierarchy:
        """The host hierarchy this solve represents — stored by
        :meth:`setup`, reconstructed (values bit-exact, via
        ``sparse.partition.partitioned_to_global``) for hierarchies built
        distributed by :meth:`setup_partitioned`.  ``rho`` estimates carry
        over unchanged so the repartitioned Chebyshev arithmetic is
        identical."""
        if self._host is not None:
            return self._host
        from ..sparse.partition import partitioned_to_global
        from .hierarchy import Level

        levels: List[Level] = []
        for lv in self.levels:
            levels.append(Level(
                A=partitioned_to_global(lv.A.part),
                P=partitioned_to_global(lv.P.part) if lv.P else None,
                R=partitioned_to_global(lv.R.part) if lv.R else None,
                rho=lv.rho,
            ))
        self._host = Hierarchy(levels)
        return self._host

    def repartition(
        self,
        mesh=None,
        axis_name: Optional[str] = None,
        procs_per_region: Optional[int] = None,
        row_weights: Optional[np.ndarray] = None,
        params: Optional[MachineParams] = None,
        reason: str = "requested",
    ) -> "DistributedHierarchy":
        """Rebuild the hierarchy onto a new geometry through the SAME cache.

        The elastic entry point: pass a smaller/larger ``mesh`` after a
        device-set change, ``row_weights`` (per-host step seconds) after a
        straggler flag, and/or re-fitted ``params`` so the Section-5
        selector re-runs under measured rates.  Every pattern is re-planned
        through ``self.cache`` — patterns the target geometry has produced
        before (e.g. growing back to a previously used device count) hit
        the surviving entries and re-plan nothing.  The returned hierarchy
        carries a ``runtime.controller.ResizeEvent`` in ``last_resize``
        with the rebuild's wall time and the plan-cache miss/hit delta.
        """
        from ..runtime.controller import cache_delta_event

        mesh = mesh if mesh is not None else self.mesh
        axis_name = axis_name if axis_name is not None else self.axis_name
        h = self._global_hierarchy()
        before = self.cache.counters()
        t0 = _now()
        with _OBS.span("amg/repartition", reason=reason,
                       old_n=self.topo.n_procs) as sp:
            new = DistributedHierarchy.setup(
                h, mesh, axis_name,
                procs_per_region=procs_per_region,
                strategy=self.strategy,
                params=params if params is not None else self.params,
                value_bytes=self.value_bytes,
                cache=self.cache,
                dtype=self.dtype,
                spmv_variant=self.spmv_variant,
                spmv_vmem_limit=self.spmv_vmem_limit,
                spmv_overlap=self.spmv_overlap,
                coarse_gather=self.coarse_gather,
                row_weights=row_weights,
            )
            sp.set(new_n=new.topo.n_procs)
        secs = _now() - t0
        new.last_resize = cache_delta_event(
            self.cache, before, reason,
            self.topo.n_procs, new.topo.n_procs, secs,
        )
        return new

    # ------------------------------------------------------- introspection
    def selection_table(self) -> List[Tuple[int, str, str, Optional[str]]]:
        """[(level, op, chosen strategy, selector report)] for every
        collective of the hierarchy."""
        rows = []
        for lv in self.levels:
            for name, op in (("A", lv.A), ("R", lv.R), ("P", lv.P)):
                if op is None:
                    continue
                rep = str(op.selection) if op.selection else None
                rows.append((lv.index, name, op.strategy, rep))
        return rows

    def kernel_table(
        self,
    ) -> List[Tuple[int, str, str, str, Optional[str]]]:
        """[(level, op, kernel variant, overlap mode, selection report)] —
        the flat-vs-blocked SpMV choice and the exchange/compute-overlap
        choice per operator, mirroring :meth:`selection_table` for the
        transport choice."""
        rows = []
        for lv in self.levels:
            for name, op in (("A", lv.A), ("R", lv.R), ("P", lv.P)):
                if op is None:
                    continue
                reps = [str(s) for s in (op.kernel, op.overlap) if s]
                rep = "; ".join(reps) if reps else None
                rows.append(
                    (lv.index, name, op.kernel_variant, op.overlap_mode, rep)
                )
        return rows

    def describe(self) -> str:
        lines = [
            f"Distributed AMG: {len(self.levels)} levels on "
            f"{self.topo.n_procs} procs ({self.topo.n_regions} regions), "
            f"plan cache: {self.cache.stats()}"
        ]
        for lv in self.levels:
            t = lv.A.coll.plan.stats.totals()
            lines.append(
                f"  L{lv.index}: n={lv.n:>8,d} pad={lv.pad:>6d} "
                f"A={lv.A.strategy:8s} kern={lv.A.kernel_variant:7s} "
                f"ov={lv.A.overlap_mode:4s} "
                f"inter_msgs={t['inter_msgs']:5d} "
                f"inter_bytes={t['inter_bytes']:8d}"
                + (f" R={lv.R.strategy} P={lv.P.strategy}" if lv.R else "")
            )
        if self.coarse_selection is not None:
            lines.append(f"  coarse_gather={self.coarse_gather}: "
                         f"{self.coarse_selection}")
        return "\n".join(lines)

    def measure_exchange_seconds(
        self, iters: int = 20, warmup: int = 3, tracer=None
    ) -> List[Tuple[int, str, float]]:
        """Measured (not modeled) per-level device exchange wall time.

        Times the jitted bound executor of each level's operator halo on
        the real mesh (shared protocol: ``core.collectives.time_executor``);
        returns [(level, strategy, seconds_per_exchange)].  Levels without
        ghost columns have no exchange and report 0.0.  When ``tracer`` (a
        ``repro.profile.TraceRecorder``) is given, each level's timing is
        recorded against its plan — the measured feed of the
        measured-vs-modeled calibration loop.  With no explicit tracer,
        any ``TraceRecorder`` attached to the enabled obs layer receives
        the same samples through the span bridge (``pure_exchange``
        span attributes) — how a production solve keeps feeding
        calibration without threading a tracer through every call.
        """
        from ..core.collectives import time_executor

        out = []
        for lv in self.levels:
            if not lv.A.ell.ghost_pad:
                out.append((lv.index, lv.A.strategy, 0.0))
                continue
            with _OBS.span("amg/measure_exchange", level=lv.index,
                           strategy=lv.A.strategy) as sp:
                secs = time_executor(
                    self._bind_exchange_only(lv.A),
                    self.topo.n_procs,
                    lv.A.ell.in_pad,
                    dtype=self.dtype,
                    iters=iters,
                    warmup=warmup,
                )
                if tracer is not None:
                    tracer.record_plan(lv.A.coll.plan, secs,
                                       label=f"amg/L{lv.index}",
                                       pure_exchange=True)
                else:
                    # no explicit tracer: let the obs bridge record it
                    # (guarded so a tracer passed here is never doubled)
                    sp.set(plan=lv.A.coll.plan, pure_exchange=True,
                           seconds=secs)
            out.append((lv.index, lv.A.strategy, secs))
        return out

    def measure_spmv_seconds(
        self, iters: int = 10, warmup: int = 2, tracer=None
    ) -> List[Tuple[int, str, str, float]]:
        """Measured per-level wall time of the full jitted distributed SpMV
        (exchange + kernel, under whatever overlap schedule each level
        selected); returns [(level, kernel variant, overlap mode, seconds)].

        When ``tracer`` is given, levels with an exchange are recorded
        against their plan with ``pure_exchange=False``: these timings
        include kernel compute (like the MoE dispatch rows), so
        ``merged_rate_samples(pure_only=True)`` must keep them out of the
        exchange-rate calibration fit.
        """
        import jax
        import jax.numpy as jnp

        rng = np.random.default_rng(0)
        out = []
        for k, lv in enumerate(self.levels):
            fn = jax.jit(self._Amv[k])
            x = jnp.asarray(
                rng.normal(
                    size=(self.topo.n_procs, lv.A.ell.in_pad)
                ).astype(self.dtype)
            )
            for _ in range(warmup + 1):
                fn(x).block_until_ready()
            t0 = _now()
            for _ in range(iters):
                y = fn(x)
            y.block_until_ready()
            secs = (_now() - t0) / iters
            if tracer is not None and lv.A.ell.ghost_pad:
                tracer.record_plan(
                    lv.A.coll.plan, secs,
                    label=f"amg/L{lv.index}/spmv", pure_exchange=False,
                )
            out.append(
                (lv.index, lv.A.kernel_variant, lv.A.overlap_mode, secs)
            )
        return out

    def _bind_exchange_only(self, op: DistOp) -> Callable:
        return self.cache.executor(
            op.part.pattern, self.topo, self.mesh, self.axis_name,
            strategy=self.strategy,
            value_bytes=self.value_bytes,
            params=self.params,
        )
