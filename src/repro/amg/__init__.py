from .stencil import diffusion_2d, paper_problem, rotated_anisotropic_stencil
from .coarsen import direct_interpolation, pmis, strength_graph
from .hierarchy import Hierarchy, Level, build_hierarchy, jacobi, solve, v_cycle
from .distributed import DistOp, DistributedHierarchy, DistributedLevel
from .distributed_setup import (
    DistributedSetup,
    ExchangeRecord,
    SetupLevel,
    distributed_build_hierarchy,
    partition_fine_matrix,
)

__all__ = [
    "diffusion_2d", "paper_problem", "rotated_anisotropic_stencil",
    "direct_interpolation", "pmis", "strength_graph",
    "Hierarchy", "Level", "build_hierarchy", "jacobi", "solve", "v_cycle",
    "DistOp", "DistributedHierarchy", "DistributedLevel",
    "DistributedSetup", "ExchangeRecord", "SetupLevel",
    "distributed_build_hierarchy", "partition_fine_matrix",
]
