"""AMG hierarchy construction (setup phase) + V-cycle solver (solve phase).

The solve phase is where the paper measures communication: one SpMV-shaped
exchange per level per iteration.  ``Hierarchy.levels[k].A`` supplies the
communication pattern analyzed by the benchmarks.

This module is the HOST reference solver.  The device-resident distributed
solve — every level partitioned, halos through persistent neighborhood
collectives, the whole V-cycle jitted — lives in
:mod:`repro.amg.distributed` (``DistributedHierarchy.setup`` /
``.solve``) and is validated against this solver's residual history.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from ..sparse.csr import CSR
from .coarsen import direct_interpolation, pmis, strength_graph


@dataclass
class Level:
    A: CSR
    P: Optional[CSR] = None  # prolongation to this level's fine grid
    R: Optional[CSR] = None  # restriction (P^T)
    rho: float = 0.0         # spectral-radius estimate of D^-1 A (Chebyshev)
    splitting: Optional[np.ndarray] = None  # C/F splitting used to coarsen
    # this level (+1 C-point, 0 F-point); the quantity the distributed
    # setup (amg.distributed_setup) must reproduce exactly


def inv_diag(A: CSR) -> np.ndarray:
    """Guarded inverse diagonal (0 where the diagonal is 0).

    The single definition shared by the host smoothers and the device
    solver (``amg.distributed``), which must stay arithmetically identical
    for the host/device residual-history cross-check to hold.
    """
    d = A.diagonal()
    return np.where(d != 0, 1.0 / np.where(d == 0, 1.0, d), 0.0)


def estimate_rho(A: CSR, iters: int = 12, seed: int = 0) -> float:
    """Power iteration on D^{-1} A (the Chebyshev smoother interval)."""
    dinv = inv_diag(A)
    rng = np.random.default_rng(seed)
    x = rng.normal(size=A.nrows)
    x /= np.linalg.norm(x) + 1e-300
    rho = 1.0
    for _ in range(iters):
        y = dinv * A.matvec(x)
        n = np.linalg.norm(y)
        if n == 0:
            return 1.0
        rho = n
        x = y / n
    return float(rho)


@dataclass
class Hierarchy:
    levels: List[Level]

    @property
    def n_levels(self) -> int:
        return len(self.levels)

    def complexity(self) -> float:
        fine = self.levels[0].A.nnz
        return sum(l.A.nnz for l in self.levels) / max(fine, 1)

    def describe(self) -> str:
        rows = [
            f"  level {i:2d}: n={l.A.nrows:>9,d} nnz={l.A.nnz:>10,d} "
            f"nnz/row={l.A.nnz / max(l.A.nrows, 1):5.1f}"
            for i, l in enumerate(self.levels)
        ]
        return "\n".join(
            [f"AMG hierarchy: {self.n_levels} levels, "
             f"operator complexity {self.complexity():.2f}"] + rows
        )


def build_hierarchy(
    A: CSR,
    max_levels: int = 25,
    min_coarse: int = 64,
    strength_theta: float = 0.25,
    seed: int = 0,
) -> Hierarchy:
    levels = [Level(A=A)]
    while (
        levels[-1].A.nrows > min_coarse and len(levels) < max_levels
    ):
        Ak = levels[-1].A
        S = strength_graph(Ak, strength_theta)
        if S.nnz == 0:
            break
        splitting = pmis(S, seed=seed + len(levels))
        P, splitting = direct_interpolation(Ak, S, splitting)
        if P.ncols >= Ak.nrows or P.ncols == 0:
            break
        levels[-1].splitting = splitting
        R = P.transpose()
        AP = Ak.matmat(P)
        Ac = R.matmat(AP).prune(1e-14)
        levels[-1].P = P
        levels[-1].R = R
        levels.append(Level(A=Ac))
    for lvl in levels:
        lvl.rho = estimate_rho(lvl.A)
    return Hierarchy(levels)


# ---------------------------------------------------------------------------
# solve phase
# ---------------------------------------------------------------------------


def jacobi(A: CSR, x: np.ndarray, b: np.ndarray, omega: float = 2.0 / 3.0,
           iters: int = 1) -> np.ndarray:
    dinv = inv_diag(A)
    for _ in range(iters):
        x = x + omega * dinv * (b - A.matvec(x))
    return x


def chebyshev(A: CSR, x: np.ndarray, b: np.ndarray, rho: float,
              degree: int = 3, lower_frac: float = 0.30) -> np.ndarray:
    """Chebyshev polynomial smoother on D^{-1}A over [lower*rho, 1.1*rho]
    (hypre-style), vectorized — a strong smoother without Gauss-Seidel's
    sequential dependence (which would serialize across the distributed
    rows and is why hypre offers l1-Jacobi/Chebyshev at scale)."""
    dinv = inv_diag(A)
    upper = 1.1 * rho
    lower = lower_frac * rho
    theta = 0.5 * (upper + lower)
    delta = 0.5 * (upper - lower)
    sigma = theta / delta
    rho_k = 1.0 / sigma
    r = dinv * (b - A.matvec(x))
    p = r / theta
    x = x + p
    for _ in range(degree - 1):
        rho_next = 1.0 / (2.0 * sigma - rho_k)
        r = dinv * (b - A.matvec(x))
        p = rho_next * rho_k * p + 2.0 * rho_next / delta * r
        x = x + p
        rho_k = rho_next
    return x


def v_cycle(h: Hierarchy, b: np.ndarray, x: Optional[np.ndarray] = None,
            level: int = 0, pre: int = 1, post: int = 1) -> np.ndarray:
    A = h.levels[level].A
    rho = h.levels[level].rho or 1.0

    def smooth(xx, sweeps):
        return chebyshev(A, xx, b, rho, degree=3 * sweeps)

    if x is None:
        x = np.zeros_like(b)
    if level == h.n_levels - 1 or h.levels[level].P is None:
        # coarsest: heavy smoothing is plenty at n<=64
        return chebyshev(A, x, b, rho, degree=24)
    x = smooth(x, pre)
    r = b - A.matvec(x)
    rc = h.levels[level].R.matvec(r)
    ec = v_cycle(h, rc, None, level + 1, pre, post)
    x = x + h.levels[level].P.matvec(ec)
    return smooth(x, post)


def solve(
    h: Hierarchy,
    b: np.ndarray,
    tol: float = 1e-8,
    max_iters: int = 100,
) -> tuple:
    """AMG-preconditioned stationary iteration; returns (x, residual_history)."""
    x = np.zeros_like(b)
    A = h.levels[0].A
    nb = np.linalg.norm(b)
    hist = []
    for _ in range(max_iters):
        r = b - A.matvec(x)
        rn = np.linalg.norm(r) / max(nb, 1e-300)
        hist.append(rn)
        if rn < tol:
            break
        x = x + v_cycle(h, r)
    return x, hist
