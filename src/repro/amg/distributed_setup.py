"""Distributed AMG *setup* on persistent neighborhood collectives.

PR 1 made the AMG solve device-resident; this module distributes the setup
phase — the irregular-communication-heavy stage the paper targets in Hypre
BoomerAMG.  Each rank owns a contiguous row block of the fine operator and
the whole pipeline (strength graph, PMIS coarsening, direct interpolation,
``R = P^T``, the Galerkin product ``A_c = R A P``) runs block-local with
every exchange routed through the existing plan machinery:

* **halo exchanges** (PMIS states/weights, splitting, coarse numbering,
  rho power iteration) execute a per-level persistent ``NeighborAlltoallV``
  over the level's row index space, cached in
  :class:`~repro.core.cache.PlanCache` by pattern fingerprint — for
  structurally symmetric operators this is the *same* pattern the solve
  phase uses, so setup and solve share one plan;
* **transpose pushes** (reverse strength edges, ``P^T``) use the sparse
  dynamic data exchange (``core.dynexchange``, arXiv 2308.13869): the
  receivers discover their partners from an allreduce on counts;
* the **Galerkin SpGEMM** fetches remote ``A``/``P`` rows through
  ``sparse.spgemm.gather_remote_rows`` (discovery + two cached
  ``NeighborAlltoallV`` exchanges) and multiplies with local merge-based
  SpGEMM — no rank ever materializes a global operator.

The result reproduces the host :func:`~repro.amg.hierarchy.build_hierarchy`
level by level: identical C/F splittings (the PMIS rounds are executed in
lock-step with halo'd neighbor states, on the same weight stream) and
coarse operators equal to 1e-12 (the only drift is Galerkin association
order and global-norm reduction order in the rho estimate).

Entry points: :func:`distributed_build_hierarchy` (from per-rank blocks),
:meth:`DistributedSetup.to_host_hierarchy` (assembled view for validation),
and ``DistributedHierarchy.setup_partitioned`` in :mod:`repro.amg.distributed`
(lowering straight to the device solve).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from ..core.cache import PlanCache, default_plan_cache
from ..core.costmodel import MachineParams, TPU_V5E
from ..core.dynexchange import DiscoveryStats, SparseDynamicExchange
from ..core.neighborhood import NeighborAlltoallV
from ..core.plan import CommPattern, Topology
from ..sparse.csr import CSR
from ..sparse.partition import block_offsets, split_rows, stack_blocks
from ..sparse.spgemm import spgemm_rap
from .hierarchy import Hierarchy, Level

UNDECIDED, CPT, FPT = 0, 1, 2


# ---------------------------------------------------------------------------
# exchange bookkeeping
# ---------------------------------------------------------------------------


@dataclass
class ExchangeRecord:
    """One setup-phase exchange: what moved, at which level, through what."""

    level: int
    phase: str                 # halo | strength_transpose | p_transpose |
    #                            gather_A | gather_P
    values: int                # values delivered (pattern ghosts / pushed rows)
    pattern: Optional[CommPattern] = None   # None for one-shot pushes
    discovery: Optional[DiscoveryStats] = None


# ---------------------------------------------------------------------------
# per-level halo: one persistent collective for every setup vector exchange
# ---------------------------------------------------------------------------


@dataclass
class _Halo:
    offsets: np.ndarray
    needs: List[np.ndarray]        # per rank: sorted unique ghost global ids
    coll: NeighborAlltoallV
    pattern: CommPattern

    def exchange(self, blocks: List[np.ndarray]) -> List[np.ndarray]:
        """Per-rank extended arrays [own block; delivered ghosts]."""
        vals = [np.asarray(b, dtype=np.float64) for b in blocks]
        ghosts = self.coll(vals)
        return [np.concatenate([v, g]) for v, g in zip(vals, ghosts)]

    def localize(self, cols: np.ndarray, p: int) -> np.ndarray:
        """Global column ids -> indices into this rank's extended array."""
        lo, hi = int(self.offsets[p]), int(self.offsets[p + 1])
        own = (cols >= lo) & (cols < hi)
        ghost_pos = np.searchsorted(self.needs[p], cols)
        return np.where(own, cols - lo, (hi - lo) + ghost_pos)


def _build_halo(
    col_sources: List[List[CSR]],
    offsets: np.ndarray,
    topo: Topology,
    cache: PlanCache,
    strategy: str,
    value_bytes: int,
    params: MachineParams,
) -> _Halo:
    """Halo over the union of ghost columns of the given per-rank blocks."""
    n_procs = len(col_sources[0])
    needs = []
    for p in range(n_procs):
        lo, hi = int(offsets[p]), int(offsets[p + 1])
        cols = np.concatenate(
            [src[p].indices.astype(np.int64) for src in col_sources]
        )
        needs.append(np.unique(cols[(cols < lo) | (cols >= hi)]))
    pattern = CommPattern.from_block_partition(needs, offsets)
    coll = cache.collective(
        pattern, topo, strategy, value_bytes=value_bytes, params=params
    )
    return _Halo(np.asarray(offsets, dtype=np.int64), needs, coll, pattern)


# ---------------------------------------------------------------------------
# distributed setup kernels (block-local + exchanges)
# ---------------------------------------------------------------------------


def _strength_block(Ab: CSR, row_base: int, theta: float) -> CSR:
    """Block-local classical strength graph (same arithmetic as the host
    ``coarsen.strength_graph``; rows are local, columns stay global)."""
    rows = Ab.row_indices()
    gcols = Ab.indices.astype(np.int64)
    offd = (rows + row_base) != gcols
    neg = np.where(offd, -Ab.data, 0.0)
    row_max = np.zeros(Ab.nrows)
    np.maximum.at(row_max, rows, neg)
    keep = offd & (neg >= theta * row_max[rows]) & (neg > 0)
    return CSR.from_coo(
        rows[keep], gcols[keep], np.ones(int(keep.sum())), Ab.shape
    )


def _symmetrize_blocks(
    S_blocks: List[CSR], offsets: np.ndarray
) -> Tuple[List[CSR], DiscoveryStats]:
    """G = S + S^T by row blocks: reverse edges are *pushed* to the owner of
    their target row via the sparse dynamic data exchange (receivers cannot
    know their senders in advance — the SDDE's defining situation)."""
    dest, payload = [], []
    for p, Sb in enumerate(S_blocks):
        rows_g = Sb.row_indices() + int(offsets[p])
        cols_g = Sb.indices.astype(np.int64)
        owner = np.searchsorted(offsets, cols_g, side="right") - 1
        dest.append(owner)
        payload.append(
            np.stack([cols_g.astype(np.float64), rows_g.astype(np.float64)],
                     axis=-1)
        )
    received, _src, stats = SparseDynamicExchange.push(dest, payload)
    G_blocks = []
    for p, Sb in enumerate(S_blocks):
        rev_rows = received[p][:, 0].astype(np.int64) - int(offsets[p])
        rev_cols = received[p][:, 1].astype(np.int64)
        rows = np.concatenate([Sb.row_indices(), rev_rows])
        cols = np.concatenate([Sb.indices.astype(np.int64), rev_cols])
        G_blocks.append(
            CSR.from_coo(rows, cols, np.ones(len(rows)), Sb.shape)
        )
    return G_blocks, stats


def _distributed_pmis(
    G_blocks: List[CSR], offsets: np.ndarray, halo: _Halo, seed: int
) -> List[np.ndarray]:
    """PMIS in lock-step with the host ``coarsen.pmis``: every round halos
    the active weights and the fresh C flags, so each rank takes exactly
    the decisions the host takes on the global graph."""
    n = int(offsets[-1])
    n_procs = len(G_blocks)
    # One global weight stream (deterministic across ranks — stands in for
    # a counter-based RNG), sliced per block: identical to the host's
    # ``deg + rng.random(n)``.
    w_rand = np.random.default_rng(seed).random(n)
    states, ws, g_rows, g_cols_ext = [], [], [], []
    for p, Gb in enumerate(G_blocks):
        deg = np.diff(Gb.indptr).astype(np.float64)
        lo, hi = int(offsets[p]), int(offsets[p + 1])
        ws.append(deg + w_rand[lo:hi])
        state = np.full(Gb.nrows, UNDECIDED, dtype=np.int8)
        state[deg == 0] = FPT
        states.append(state)
        g_rows.append(Gb.row_indices())
        g_cols_ext.append(halo.localize(Gb.indices.astype(np.int64), p))

    while any(np.any(s == UNDECIDED) for s in states):
        active = [
            np.where(s == UNDECIDED, w, -1.0) for s, w in zip(states, ws)
        ]
        ext_w = halo.exchange(active)
        new_c = []
        for p in range(n_procs):
            m = G_blocks[p].nrows
            nbr_max = np.zeros(m)
            edge_active = states[p][g_rows[p]] == UNDECIDED
            np.maximum.at(
                nbr_max, g_rows[p][edge_active],
                ext_w[p][g_cols_ext[p][edge_active]],
            )
            new_c.append(
                (states[p] == UNDECIDED) & (active[p] > nbr_max)
            )
        if not any(c.any() for c in new_c):
            # global deterministic tie-break: first undecided point
            # (allreduce-min of the per-rank candidates)
            firsts = [
                int(offsets[p]) + int(np.flatnonzero(states[p] == UNDECIDED)[0])
                for p in range(n_procs)
                if np.any(states[p] == UNDECIDED)
            ]
            g = min(firsts)
            owner = int(np.searchsorted(offsets, g, side="right") - 1)
            new_c[owner][g - int(offsets[owner])] = True
        for p in range(n_procs):
            states[p][new_c[p]] = CPT
        ext_c = halo.exchange([c.astype(np.float64) for c in new_c])
        for p in range(n_procs):
            hit = (
                (ext_c[p][g_cols_ext[p]] > 0.0)
                & (states[p][g_rows[p]] == UNDECIDED)
            )
            states[p][g_rows[p][hit]] = FPT
    return [(s == CPT).astype(np.int8) for s in states]


def _distributed_interpolation(
    A_blocks: List[CSR],
    S_blocks: List[CSR],
    splitting: List[np.ndarray],
    offsets: np.ndarray,
    halo: _Halo,
) -> Tuple[List[CSR], List[np.ndarray], np.ndarray]:
    """Direct interpolation with halo'd splitting / coarse numbering;
    mirrors ``coarsen.direct_interpolation`` row for row."""
    n = int(offsets[-1])
    n_procs = len(A_blocks)
    splitting = [s.copy() for s in splitting]

    arows, acols_g, acols_ext, avals, strong, deg_strong = [], [], [], [], [], []
    for p, Ab in enumerate(A_blocks):
        r = Ab.row_indices()
        c = Ab.indices.astype(np.int64)
        arows.append(r)
        acols_g.append(c)
        acols_ext.append(halo.localize(c, p))
        avals.append(Ab.data)
        # membership of A edges in the strength pattern: CSR order makes the
        # (row, col) keys already sorted, so a searchsorted probes suffice
        Sb = S_blocks[p]
        key_s = Sb.row_indices() * n + Sb.indices.astype(np.int64)
        key_a = r * n + c
        if len(key_s):
            pos = np.minimum(np.searchsorted(key_s, key_a), len(key_s) - 1)
            strong.append(key_s[pos] == key_a)
        else:
            strong.append(np.zeros(len(key_a), dtype=bool))
        deg_strong.append(np.diff(Sb.indptr))

    for _pass in range(30):  # promote until every F has a strong C neighbor
        ext_split = halo.exchange([s.astype(np.float64) for s in splitting])
        updates = []
        for p in range(n_procs):
            interp_edge = strong[p] & (ext_split[p][acols_ext[p]] == 1.0)
            has_c = np.zeros(A_blocks[p].nrows, dtype=bool)
            has_c[arows[p][interp_edge]] = True
            bad_f = (splitting[p] == 0) & ~has_c & (deg_strong[p] > 0)
            updates.append(bad_f)
        if not any(u.any() for u in updates):
            break
        for p in range(n_procs):
            splitting[p][updates[p]] = 1

    # global coarse numbering: exclusive scan of per-rank C counts
    counts = np.array([int((s == 1).sum()) for s in splitting], dtype=np.int64)
    coff = np.concatenate([[0], np.cumsum(counts)])
    n_coarse = int(coff[-1])
    cmaps = []
    for p in range(n_procs):
        cmap = -np.ones(A_blocks[p].nrows)
        cmap[splitting[p] == 1] = coff[p] + np.arange(counts[p])
        cmaps.append(cmap)
    ext_split = halo.exchange([s.astype(np.float64) for s in splitting])
    ext_cmap = halo.exchange(cmaps)

    P_blocks = []
    for p in range(n_procs):
        Ab = A_blocks[p]
        m = Ab.nrows
        base = int(offsets[p])
        r, c, v = arows[p], acols_g[p], avals[p]
        diag = np.zeros(m)
        on_diag = c == (r + base)
        diag[r[on_diag]] = v[on_diag]
        offd = ~on_diag
        neg = np.where(offd & (v < 0), v, 0.0)
        row_neg_sum = np.zeros(m)
        np.add.at(row_neg_sum, r, neg)
        split_at_col = ext_split[p][acols_ext[p]]
        interp_edge = strong[p] & (split_at_col == 1.0) & (v < 0)
        row_cneg_sum = np.zeros(m)
        np.add.at(row_cneg_sum, r[interp_edge], v[interp_edge])

        fmask = interp_edge & (splitting[p][r] == 0)
        ri, vi = r[fmask], v[fmask]
        pcol_f = ext_cmap[p][acols_ext[p][fmask]].astype(np.int64)
        alpha = np.where(
            row_cneg_sum[ri] != 0, row_neg_sum[ri] / row_cneg_sum[ri], 0.0
        )
        w = -alpha * vi / diag[ri]

        local_c = np.flatnonzero(splitting[p] == 1)
        prow = np.concatenate([ri, local_c])
        pcol = np.concatenate(
            [pcol_f, coff[p] + np.arange(counts[p], dtype=np.int64)]
        )
        pval = np.concatenate([w, np.ones(counts[p])])
        P_blocks.append(CSR.from_coo(prow, pcol, pval, (m, n_coarse)))
    return P_blocks, splitting, coff


def _transpose_blocks(
    P_blocks: List[CSR], fine_offsets: np.ndarray, coarse_offsets: np.ndarray
) -> Tuple[List[CSR], DiscoveryStats]:
    """R = P^T by coarse row blocks: each P entry is pushed to the owner of
    its coarse row (sparse dynamic data exchange — the owner cannot know
    which ranks interpolate from its C-points)."""
    n_fine = int(fine_offsets[-1])
    dest, payload = [], []
    for p, Pb in enumerate(P_blocks):
        rows_g = Pb.row_indices() + int(fine_offsets[p])
        cols_g = Pb.indices.astype(np.int64)
        owner = np.searchsorted(coarse_offsets, cols_g, side="right") - 1
        dest.append(owner)
        payload.append(
            np.stack(
                [cols_g.astype(np.float64), rows_g.astype(np.float64), Pb.data],
                axis=-1,
            )
        )
    received, _src, stats = SparseDynamicExchange.push(dest, payload)
    R_blocks = []
    for q in range(len(P_blocks)):
        got = received[q]
        rows = got[:, 0].astype(np.int64) - int(coarse_offsets[q])
        cols = got[:, 1].astype(np.int64)
        m = int(coarse_offsets[q + 1] - coarse_offsets[q])
        R_blocks.append(CSR.from_coo(rows, cols, got[:, 2], (m, n_fine)))
    return R_blocks, stats


def _block_inv_diag(Ab: CSR, row_base: int) -> np.ndarray:
    """Guarded inverse diagonal of a row block (matches ``hierarchy.inv_diag``)."""
    r = Ab.row_indices()
    c = Ab.indices.astype(np.int64)
    d = np.zeros(Ab.nrows)
    on_diag = c == (r + row_base)
    d[r[on_diag]] = Ab.data[on_diag]
    return np.where(d != 0, 1.0 / np.where(d == 0, 1.0, d), 0.0)


def _distributed_rho(
    A_blocks: List[CSR],
    offsets: np.ndarray,
    halo: _Halo,
    iters: int = 12,
    seed: int = 0,
) -> float:
    """Power iteration on D^-1 A with halo'd matvecs (same stream as the
    host ``estimate_rho``; global norms reduce block partials, so the
    estimate drifts from the host's only by summation order)."""
    n = int(offsets[-1])
    n_procs = len(A_blocks)
    A_loc = []
    dinvs = []
    for p, Ab in enumerate(A_blocks):
        cols_ext = halo.localize(Ab.indices.astype(np.int64), p)
        width = Ab.nrows + len(halo.needs[p])
        A_loc.append(
            CSR((Ab.nrows, max(width, 1)), Ab.indptr,
                cols_ext.astype(np.int32), Ab.data)
        )
        dinvs.append(_block_inv_diag(Ab, int(offsets[p])))
    x_glob = np.random.default_rng(seed).normal(size=n)
    xs = [x_glob[int(offsets[p]):int(offsets[p + 1])] for p in range(n_procs)]

    def gnorm(blocks):
        return float(np.sqrt(sum(float(np.dot(b, b)) for b in blocks)))

    nx = gnorm(xs) + 1e-300
    xs = [b / nx for b in xs]
    rho = 1.0
    for _ in range(iters):
        ext = halo.exchange(xs)
        ys = [
            dinvs[p] * A_loc[p].matvec(ext[p][: A_loc[p].ncols])
            for p in range(n_procs)
        ]
        nrm = gnorm(ys)
        if nrm == 0:
            return 1.0
        rho = nrm
        xs = [y / nrm for y in ys]
    return float(rho)


# ---------------------------------------------------------------------------
# the distributed setup driver
# ---------------------------------------------------------------------------


@dataclass
class SetupLevel:
    """One level of the distributed hierarchy, stored as per-rank blocks."""

    row_offsets: np.ndarray
    A_blocks: List[CSR]
    rho: float = 0.0
    splitting_blocks: Optional[List[np.ndarray]] = None
    coarse_offsets: Optional[np.ndarray] = None
    P_blocks: Optional[List[CSR]] = None
    R_blocks: Optional[List[CSR]] = None

    @property
    def nrows(self) -> int:
        return int(self.row_offsets[-1])

    @property
    def nnz(self) -> int:
        return int(sum(b.nnz for b in self.A_blocks))

    def splitting(self) -> Optional[np.ndarray]:
        if self.splitting_blocks is None:
            return None
        return np.concatenate(self.splitting_blocks)


@dataclass
class DistributedSetup:
    """A hierarchy built end-to-end from a partitioned fine-grid matrix."""

    levels: List[SetupLevel]
    topo: Topology
    cache: PlanCache
    records: List[ExchangeRecord] = field(default_factory=list)

    @property
    def n_levels(self) -> int:
        return len(self.levels)

    def to_host_hierarchy(self) -> Hierarchy:
        """Assembled (global) view — validation / host-solver interop only;
        the device lowering goes straight from the blocks."""
        out = []
        for sl in self.levels:
            lvl = Level(
                A=stack_blocks(sl.A_blocks),
                rho=sl.rho,
                splitting=sl.splitting(),
            )
            if sl.P_blocks is not None:
                lvl.P = stack_blocks(sl.P_blocks)
                lvl.R = stack_blocks(sl.R_blocks)
            out.append(lvl)
        return Hierarchy(out)

    def exchange_summary(self) -> dict:
        """Total setup-phase traffic by phase: values moved + discovery cost."""
        out: dict = {}
        for rec in self.records:
            d = out.setdefault(
                rec.phase, {"values": 0, "exchanges": 0, "allreduce_ints": 0}
            )
            d["values"] += rec.values
            d["exchanges"] += 1
            if rec.discovery is not None:
                d["allreduce_ints"] += rec.discovery.allreduce_ints
        return out

    def describe(self) -> str:
        lines = [
            f"Distributed AMG setup: {self.n_levels} levels on "
            f"{self.topo.n_procs} ranks ({self.topo.n_regions} regions), "
            f"plan cache: {self.cache.stats()}"
        ]
        for k, sl in enumerate(self.levels):
            sizes = np.diff(sl.row_offsets)
            lines.append(
                f"  L{k}: n={sl.nrows:>8,d} nnz={sl.nnz:>9,d} "
                f"rows/rank [{int(sizes.min())},{int(sizes.max())}]"
            )
        for phase, d in sorted(self.exchange_summary().items()):
            lines.append(
                f"  exchange {phase:20s}: {d['exchanges']:3d} exchanges, "
                f"{d['values']:8d} values, allreduce {d['allreduce_ints']} ints"
            )
        return "\n".join(lines)


def distributed_build_hierarchy(
    A_blocks: List[CSR],
    row_offsets: np.ndarray,
    topo: Topology,
    cache: Optional[PlanCache] = None,
    max_levels: int = 25,
    min_coarse: int = 64,
    strength_theta: float = 0.25,
    seed: int = 0,
    strategy: str = "auto",
    value_bytes: int = 8,
    params: MachineParams = TPU_V5E,
) -> DistributedSetup:
    """Build the AMG hierarchy from per-rank row blocks of the fine matrix.

    Mirrors the host :func:`~repro.amg.hierarchy.build_hierarchy` decision
    for decision (same thresholds, same seeds, same promote rules) while
    running block-local with all exchanges through cached persistent
    collectives; see the module docstring for the exchange inventory.
    """
    row_offsets = np.asarray(row_offsets, dtype=np.int64)
    assert len(A_blocks) == topo.n_procs, (len(A_blocks), topo.n_procs)
    cache = cache if cache is not None else default_plan_cache()
    records: List[ExchangeRecord] = []
    levels = [SetupLevel(row_offsets, list(A_blocks))]
    halos: List[_Halo] = []

    def halo_for(level_idx: int, col_sources) -> _Halo:
        sl = levels[level_idx]
        halo = _build_halo(
            col_sources, sl.row_offsets, topo, cache,
            strategy, value_bytes, params,
        )
        records.append(
            ExchangeRecord(
                level_idx, "halo", halo.pattern.total_ghosts(), halo.pattern
            )
        )
        return halo

    while levels[-1].nrows > min_coarse and len(levels) < max_levels:
        k = len(levels) - 1
        sl = levels[-1]
        offs = sl.row_offsets
        S_blocks = [
            _strength_block(Ab, int(offs[p]), strength_theta)
            for p, Ab in enumerate(sl.A_blocks)
        ]
        if sum(b.nnz for b in S_blocks) == 0:
            break
        G_blocks, sym_stats = _symmetrize_blocks(S_blocks, offs)
        records.append(
            ExchangeRecord(
                k, "strength_transpose", sym_stats.request_ints,
                discovery=sym_stats,
            )
        )
        halo = halo_for(k, [sl.A_blocks, G_blocks])
        halos.append(halo)

        splitting = _distributed_pmis(
            G_blocks, offs, halo, seed=seed + len(levels)
        )
        P_blocks, splitting, coff = _distributed_interpolation(
            sl.A_blocks, S_blocks, splitting, offs, halo
        )
        n_coarse = int(coff[-1])
        if n_coarse >= sl.nrows or n_coarse == 0:
            break
        R_blocks, t_stats = _transpose_blocks(P_blocks, offs, coff)
        records.append(
            ExchangeRecord(
                k, "p_transpose", t_stats.request_ints, discovery=t_stats
            )
        )
        rap = spgemm_rap(
            R_blocks, sl.A_blocks, P_blocks, offs, topo, cache,
            strategy=strategy, value_bytes=value_bytes, params=params,
        )
        records.append(
            ExchangeRecord(
                k, "gather_A", rap.gather_A.total_values,
                rap.gather_A.payload_pattern, rap.gather_A.discovery,
            )
        )
        records.append(
            ExchangeRecord(
                k, "gather_P", rap.gather_P.total_values,
                rap.gather_P.payload_pattern, rap.gather_P.discovery,
            )
        )
        sl.splitting_blocks = splitting
        sl.coarse_offsets = coff
        sl.P_blocks = P_blocks
        sl.R_blocks = R_blocks
        levels.append(
            SetupLevel(coff, [b.prune(1e-14) for b in rap.Ac_blocks])
        )

    # rho estimates: reuse each coarsened level's halo; the last level (and
    # a level that broke out early) gets an A-pattern halo of its own
    for k, sl in enumerate(levels):
        if k < len(halos):
            halo = halos[k]
        else:
            halo = halo_for(k, [sl.A_blocks])
        sl.rho = _distributed_rho(sl.A_blocks, sl.row_offsets, halo)
    return DistributedSetup(levels, topo, cache, records)


def partition_fine_matrix(A: CSR, n_procs: int) -> Tuple[List[CSR], np.ndarray]:
    """Convenience: balanced contiguous row blocks of a fine-grid operator."""
    offsets = block_offsets(A.nrows, n_procs)
    return split_rows(A, offsets), offsets
