"""Rotated anisotropic diffusion operator (the paper's test problem).

-div(K grad u) on a regular 2-D grid, K = Q(theta)^T diag(1, eps) Q(theta),
discretized with the classical 7-point finite-difference stencil for
operators with mixed derivatives: center, E, W, N, S and the two corners
along the strong-coupling diagonal (NE/SW for positive cross term).  At
theta=45 deg this is exactly the paper's "7-point rotated anisotropic
diffusion system" (rotation 45 deg, anisotropy 0.001).
"""
from __future__ import annotations

import numpy as np

from ..sparse.csr import CSR


def rotated_anisotropic_stencil(theta: float, eps: float):
    """Return [(dy, dx, coeff), ...] of the 7-point stencil."""
    C, S = np.cos(theta), np.sin(theta)
    a = C * C + eps * S * S        # Kxx
    c = S * S + eps * C * C        # Kyy
    b = (1.0 - eps) * C * S        # Kxy
    # L = -(a u_xx + 2 b u_xy + c u_yy); u_xy via 7-point corner scheme.
    # Positive b couples the NE/SW diagonal; negative b couples NW/SE.
    corner = (1, 1) if b >= 0 else (1, -1)
    bb = abs(b)
    entries = [
        (0, 0, 2 * a + 2 * c - 2 * bb),
        (0, 1, -a + bb),
        (0, -1, -a + bb),
        (1, 0, -c + bb),
        (-1, 0, -c + bb),
        (corner[0], corner[1], -bb),
        (-corner[0], -corner[1], -bb),
    ]
    return entries


def diffusion_2d(
    ny: int, nx: int, theta: float = np.pi / 4, eps: float = 1e-3
) -> CSR:
    """Assemble the 7-point rotated anisotropic diffusion matrix (Dirichlet)."""
    stencil = rotated_anisotropic_stencil(theta, eps)
    n = ny * nx
    ys, xs = np.divmod(np.arange(n, dtype=np.int64), nx)
    rows_list, cols_list, vals_list = [], [], []
    for dy, dx, coeff in stencil:
        if coeff == 0.0:
            continue
        yy = ys + dy
        xx = xs + dx
        ok = (yy >= 0) & (yy < ny) & (xx >= 0) & (xx < nx)
        rows_list.append(np.arange(n, dtype=np.int64)[ok])
        cols_list.append((yy * nx + xx)[ok])
        vals_list.append(np.full(int(ok.sum()), coeff))
    return CSR.from_coo(
        np.concatenate(rows_list),
        np.concatenate(cols_list),
        np.concatenate(vals_list),
        (n, n),
    )


def paper_problem(rows: int = 524_288) -> CSR:
    """The paper's system: 524,288 rows, theta=45deg, eps=0.001.

    We use a 1024 x 512 grid (exactly 524,288 rows)."""
    nx = 1 << int(np.ceil(np.log2(np.sqrt(rows))))
    ny = rows // nx
    assert nx * ny == rows, (nx, ny, rows)
    return diffusion_2d(ny, nx)
