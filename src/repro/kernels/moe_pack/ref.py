"""Pure-jnp oracles for MoE pack/combine."""
from __future__ import annotations

import jax.numpy as jnp


def gather_rows_ref(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    return x[idx]


def combine_rows_ref(buf: jnp.ndarray, idx: jnp.ndarray, w: jnp.ndarray):
    # out[t] = sum_k w[t,k] * buf[idx[t,k]]
    gathered = buf[idx]                      # [T, K, D]
    return jnp.einsum(
        "tk,tkd->td", w.astype(jnp.float32), gathered.astype(jnp.float32)
    ).astype(buf.dtype)
