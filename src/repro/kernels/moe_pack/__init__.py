from .ops import combine, pack
from .ref import combine_rows_ref, gather_rows_ref

__all__ = ["combine", "pack", "combine_rows_ref", "gather_rows_ref"]
