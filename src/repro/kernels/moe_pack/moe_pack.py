"""MoE dispatch pack / combine as Pallas TPU kernels.

The paper's aggregation steps (s: pack values for each destination region;
r: fan received values out to final consumers) are, on device, row
gather/scatter over token buffers — the compute hot spot of the
locality-aware MoE dispatch.  Both directions are expressed as *gathers*
(never scatter-add) so blocks race-free parallelize over the grid:

pack:     out[i]    = x[idx[i]]                     (build per-expert buffers)
combine:  out[t]    = sum_k w[t, k] * buf[idx[t, k]] (weighted un-pack, top-K)

Feature dim is tiled (BD) so arbitrarily wide hidden states stream through
VMEM; the row table (x / buf) is resident per feature tile.  For token
counts whose row table exceeds VMEM the production variant swaps the
BlockSpec of ``x`` to HBM (pltpu.ANY) + double-buffered ``make_async_copy``
row DMA; the AMG/LM shapes in this repo fit the resident form.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from ...compat import pallas_tpu_compiler_params

DEFAULT_BLOCK_M = 256
DEFAULT_BLOCK_D = 512


def _pack_kernel(idx_ref, x_ref, o_ref):
    idx = idx_ref[...]            # [BM, 1] int32
    x = x_ref[...]                # [N, BD]
    o_ref[...] = x[idx[:, 0]]     # [BM, BD]


def gather_rows(
    x: jnp.ndarray,      # [N, D]  (append a zero row for pad indices = N-1)
    idx: jnp.ndarray,    # [M] int32
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
) -> jnp.ndarray:
    N, D = x.shape
    M = idx.shape[0]
    bm = min(block_m, M)
    bd = min(block_d, D)
    assert M % bm == 0 and D % bd == 0, (M, bm, D, bd)
    return pl.pallas_call(
        _pack_kernel,
        grid=(M // bm, D // bd),
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((N, bd), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, D), x.dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(idx[:, None].astype(jnp.int32), x)


def _combine_kernel(idx_ref, w_ref, buf_ref, o_ref, *, top_k: int):
    idx = idx_ref[...]            # [BM, K]
    w = w_ref[...]                # [BM, K]
    buf = buf_ref[...]            # [N, BD]
    acc = jnp.zeros((idx.shape[0], buf.shape[1]), jnp.float32)
    for k in range(top_k):        # K is small & static: unrolled
        rows = buf[idx[:, k]]     # [BM, BD]
        acc = acc + w[:, k:k + 1].astype(jnp.float32) * rows.astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def combine_rows(
    buf: jnp.ndarray,    # [N, D] expert outputs (+ zero pad row at N-1)
    idx: jnp.ndarray,    # [T, K] positions in buf
    w: jnp.ndarray,      # [T, K] combine weights
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
) -> jnp.ndarray:
    N, D = buf.shape
    T, K = idx.shape
    bm = min(block_m, T)
    bd = min(block_d, D)
    assert T % bm == 0 and D % bd == 0, (T, bm, D, bd)
    kernel = functools.partial(_combine_kernel, top_k=K)
    return pl.pallas_call(
        kernel,
        grid=(T // bm, D // bd),
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((N, bd), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((T, D), buf.dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(idx.astype(jnp.int32), w, buf)
