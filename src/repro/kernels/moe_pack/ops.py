"""Public MoE pack/combine ops with backend dispatch + padding."""
from __future__ import annotations

import jax.numpy as jnp

from .. import backend
from .moe_pack import combine_rows, gather_rows
from .ref import combine_rows_ref, gather_rows_ref


def _pad_rows(x, mult):
    rem = (-x.shape[0]) % mult
    return jnp.pad(x, [(0, rem)] + [(0, 0)] * (x.ndim - 1)) if rem else x


def pack(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """out[i] = x[idx[i]]; idx may contain N-1 pointing at a pad row."""
    mode = backend()
    if mode == "reference":
        return gather_rows_ref(x, idx)
    M, D = idx.shape[0], x.shape[1]
    bm = 256
    while M % bm and bm > 8:
        bm //= 2
    bd = 512
    while D % bd and bd > 8:
        bd //= 2
    if M % bm:
        bm = M
    if D % bd:
        bd = D
    return gather_rows(
        x, idx, block_m=bm, block_d=bd,
        interpret=(mode == "pallas_interpret"),
    )


def combine(buf: jnp.ndarray, idx: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    mode = backend()
    if mode == "reference":
        return combine_rows_ref(buf, idx, w)
    T, D = idx.shape[0], buf.shape[1]
    bm = 256
    while T % bm and bm > 8:
        bm //= 2
    bd = 512
    while D % bd and bd > 8:
        bd //= 2
    if T % bm:
        bm = T
    if D % bd:
        bd = D
    return combine_rows(
        buf, idx, w, block_m=bm, block_d=bd,
        interpret=(mode == "pallas_interpret"),
    )
