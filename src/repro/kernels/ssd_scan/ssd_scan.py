"""Mamba-2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

Per head: state S_t = exp(dt_t A) S_{t-1} + dt_t B_t (x) x_t;  y_t = C_t S_t.
The chunked (block-parallel) form computes, per chunk of length L:
  intra-chunk:  y[t] += sum_{s<=t} (C_t.B_s) exp(l_t - l_s) dt_s x_s
                (one [L,L] masked matmul feeding the MXU)
  inter-chunk:  y[t] += exp(l_t) C_t S_prev
  state update: S = exp(l_L) S_prev + sum_s exp(l_L - l_s) dt_s B_s (x) x_s

Grid = (heads, num_chunks) with the chunk dimension "arbitrary" (sequential)
so the running state lives in a VMEM scratch accumulator across chunk steps —
the TPU-native equivalent of Mamba-2's inter-chunk recurrence.  VMEM per
step: x/y [L,P] + B/C [L,N] + [L,L] intra matrix + state [N,P]; at the
default L=128, P=64, N=128 that is ~0.35 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from ...compat import pallas_tpu_compiler_params

DEFAULT_CHUNK = 128


def _ssd_kernel(
    x_ref,    # [1, L, P]
    dt_ref,   # [1, L]
    a_ref,    # [1, 1]   (A scalar for this head)
    b_ref,    # [1, L, N]
    c_ref,    # [1, L, N]
    y_ref,    # [1, L, P]
    state_scr,  # VMEM [N, P] float32
    *,
    chunk: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)     # [L, P]
    dt = dt_ref[0].astype(jnp.float32)   # [L]
    A = a_ref[0, 0].astype(jnp.float32)  # scalar
    B = b_ref[0].astype(jnp.float32)     # [L, N]
    C = c_ref[0].astype(jnp.float32)     # [L, N]

    log_a = dt * A                        # [L]  (A < 0)
    l_cum = jnp.cumsum(log_a)             # inclusive cumulative log decay
    l_tot = l_cum[-1]

    # intra-chunk: M[t,s] = (C_t . B_s) * exp(l_t - l_s) * dt_s, s <= t
    cb = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [L, L]
    li = l_cum[:, None]
    ls = l_cum[None, :]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(s_idx <= t_idx, jnp.exp(li - ls), 0.0)
    M = cb * decay * dt[None, :]
    y = jax.lax.dot(M, x, preferred_element_type=jnp.float32)  # [L, P]

    # inter-chunk: y[t] += exp(l_t) * C_t @ S_prev
    S_prev = state_scr[...]               # [N, P]
    y = y + jnp.exp(l_cum)[:, None] * jax.lax.dot(
        C, S_prev, preferred_element_type=jnp.float32
    )

    # state update: S = exp(l_tot) S_prev + sum_s exp(l_tot - l_s) dt_s B_s x_s
    w = jnp.exp(l_tot - l_cum) * dt       # [L]
    S_new = jnp.exp(l_tot) * S_prev + jax.lax.dot_general(
        B * w[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [N, P]
    state_scr[...] = S_new
    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan_h(
    x: jnp.ndarray,    # [H, T, P]
    dt: jnp.ndarray,   # [H, T]
    A: jnp.ndarray,    # [H]
    B: jnp.ndarray,    # [H, T, N]
    C: jnp.ndarray,    # [H, T, N]
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
) -> jnp.ndarray:
    """Per-head SSD scan; T must be a multiple of ``chunk`` (ops.py pads)."""
    H, T, P = x.shape
    N = B.shape[-1]
    nc = T // chunk
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk), lambda h, c: (h, c)),
            pl.BlockSpec((1, 1), lambda h, c: (h, 0)),
            pl.BlockSpec((1, chunk, N), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda h, c: (h, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda h, c: (h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((H, T, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt, A[:, None], B, C)
