"""Oracles for the SSD scan.

``ssd_ref``          — literal per-timestep recurrence (lax.scan): the ground
                       truth used by kernel tests.
``ssd_chunked_ref``  — vectorized chunked form in pure jnp: mathematically
                       identical, MXU-friendly; this is what model code runs
                       on the ``reference`` backend so HLO FLOPs match the
                       kernel's algorithm.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, B, C):
    """x: [H,T,P], dt: [H,T], A: [H], B,C: [H,T,N] -> y [H,T,P]."""
    H, T, P = x.shape
    N = B.shape[-1]

    def step(S, inp):
        xt, dtt, Bt, Ct, At = inp  # [H,P],[H],[H,N],[H,N],[H]
        a = jnp.exp(dtt * At)[:, None, None]          # [H,1,1]
        S = a * S + (dtt[:, None] * Bt)[..., None] * xt[:, None, :]  # [H,N,P]
        y = jnp.einsum("hn,hnp->hp", Ct, S)
        return S, y

    S0 = jnp.zeros((H, N, P), jnp.float32)
    xs = (
        jnp.moveaxis(x, 1, 0).astype(jnp.float32),
        jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
        jnp.moveaxis(B, 1, 0).astype(jnp.float32),
        jnp.moveaxis(C, 1, 0).astype(jnp.float32),
        jnp.broadcast_to(A.astype(jnp.float32), (T,) + A.shape),
    )
    _, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # [H,T,P]


def ssd_chunked_ref(x, dt, A, B, C, chunk: int = 128):
    """Chunked SSD identical to the kernel's algorithm, vectorized over
    (head, chunk) with a scan across chunks for the state recurrence."""
    H, T, P = x.shape
    N = B.shape[-1]
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    L = chunk
    xc = x.reshape(H, nc, L, P).astype(jnp.float32)
    dtc = dt.reshape(H, nc, L).astype(jnp.float32)
    Bc = B.reshape(H, nc, L, N).astype(jnp.float32)
    Cc = C.reshape(H, nc, L, N).astype(jnp.float32)
    log_a = dtc * A[:, None, None].astype(jnp.float32)  # [H,nc,L]
    l_cum = jnp.cumsum(log_a, axis=-1)
    l_tot = l_cum[..., -1]                               # [H,nc]

    # intra-chunk
    cb = jnp.einsum("hctn,hcsn->hcts", Cc, Bc)
    t_idx = jnp.arange(L)[:, None]
    s_idx = jnp.arange(L)[None, :]
    causal = (s_idx <= t_idx).astype(jnp.float32)
    decay = jnp.exp(l_cum[..., :, None] - l_cum[..., None, :]) * causal
    M = cb * decay * dtc[..., None, :]
    y_intra = jnp.einsum("hcts,hcsp->hctp", M, xc)

    # per-chunk state contribution
    w = jnp.exp(l_tot[..., None] - l_cum) * dtc          # [H,nc,L]
    S_chunk = jnp.einsum("hcln,hclp->hcnp", Bc * w[..., None], xc)

    # scan across chunks: S_out[c] = state *entering* chunk c
    def step(S, inp):
        S_c, g = inp  # [H,N,P], [H]
        S_next = jnp.exp(g)[:, None, None] * S + S_c
        return S_next, S

    S0 = jnp.zeros((H, N, P), jnp.float32)
    _, S_in = jax.lax.scan(
        step, S0,
        (jnp.moveaxis(S_chunk, 1, 0), jnp.moveaxis(l_tot, 1, 0)),
    )
    S_in = jnp.moveaxis(S_in, 0, 1)                      # [H,nc,N,P]
    y_inter = jnp.exp(l_cum)[..., None] * jnp.einsum(
        "hcln,hcnp->hclp", Cc, S_in
    )
    y = (y_intra + y_inter).reshape(H, T, P)
    return y.astype(x.dtype)
