"""Public SSD op: backend dispatch, batching, group broadcast, padding."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import backend
from .ref import ssd_chunked_ref, ssd_ref
from .ssd_scan import DEFAULT_CHUNK, ssd_scan_h


def _pad_time(x, axis, mult):
    rem = (-x.shape[axis]) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def ssd(
    x: jnp.ndarray,    # [Bt, T, H, P]
    dt: jnp.ndarray,   # [Bt, T, H]   (post-softplus)
    A: jnp.ndarray,    # [H]          (negative)
    B: jnp.ndarray,    # [Bt, T, G, N]
    C: jnp.ndarray,    # [Bt, T, G, N]
    *,
    chunk: int = DEFAULT_CHUNK,
) -> jnp.ndarray:
    """Batched SSD with B/C groups broadcast over heads (H % G == 0)."""
    Bt, T, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2)  # [Bt, T, H, N]
    Ch = jnp.repeat(C, rep, axis=2)

    # to per-head layout [H, T, *]
    xh = jnp.moveaxis(x, 2, 1)       # [Bt, H, T, P]
    dth = jnp.moveaxis(dt, 2, 1)     # [Bt, H, T]
    Bhh = jnp.moveaxis(Bh, 2, 1)
    Chh = jnp.moveaxis(Ch, 2, 1)

    mode = backend()
    if mode == "reference":
        fn = lambda xx, dd, bb, cc: ssd_chunked_ref(
            xx, dd, A, bb, cc, chunk=min(chunk, max(8, xx.shape[1]))
        ) if xx.shape[1] % min(chunk, max(8, xx.shape[1])) == 0 else ssd_ref(
            xx, dd, A, bb, cc
        )
        y = jax.vmap(fn)(xh, dth, Bhh, Chh)
    else:
        ck = min(chunk, T) if T % min(chunk, T) == 0 else chunk
        Tp = T + ((-T) % ck)
        xh2 = _pad_time(xh, 2, ck)
        dth2 = _pad_time(dth, 2, ck)
        Bh2 = _pad_time(Bhh, 2, ck)
        Ch2 = _pad_time(Chh, 2, ck)
        y = jax.vmap(
            lambda xx, dd, bb, cc: ssd_scan_h(
                xx, dd, A, bb, cc, chunk=ck,
                interpret=(mode == "pallas_interpret"),
            )
        )(xh2, dth2, Bh2, Ch2)[:, :, :T]
    return jnp.moveaxis(y, 1, 2)     # [Bt, T, H, P]


def ssd_decode_step(
    S: jnp.ndarray,    # [Bt, H, N, P] running state
    x: jnp.ndarray,    # [Bt, H, P]
    dt: jnp.ndarray,   # [Bt, H]
    A: jnp.ndarray,    # [H]
    B: jnp.ndarray,    # [Bt, G, N]
    C: jnp.ndarray,    # [Bt, G, N]
):
    """Single-token recurrence for serving (O(1) per token — the reason SSMs
    run the long_500k shape). Returns (S_new, y)."""
    G = B.shape[1]
    H = x.shape[1]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=1)  # [Bt, H, N]
    Ch = jnp.repeat(C, rep, axis=1)
    a = jnp.exp(dt * A[None, :])[..., None, None]        # [Bt,H,1,1]
    S_new = a * S + (dt[..., None] * Bh)[..., None] * x[:, :, None, :]
    y = jnp.einsum("bhn,bhnp->bhp", Ch, S_new)
    return S_new, y.astype(x.dtype)
