from .ops import ssd, ssd_decode_step
from .ref import ssd_chunked_ref, ssd_ref

__all__ = ["ssd", "ssd_decode_step", "ssd_chunked_ref", "ssd_ref"]
