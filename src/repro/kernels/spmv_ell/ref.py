"""Pure-jnp oracle for ELL SpMV."""
from __future__ import annotations

import jax.numpy as jnp


def spmv_ell_ref(cols: jnp.ndarray, vals: jnp.ndarray, x: jnp.ndarray):
    """cols/vals: [R, K]; x: [N] -> y [R]."""
    return jnp.sum(vals * x[cols], axis=1)
