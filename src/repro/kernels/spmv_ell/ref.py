"""Pure-jnp oracles for ELL SpMV (flat and column-blocked layouts)."""
from __future__ import annotations

import jax.numpy as jnp


def spmv_ell_ref(cols: jnp.ndarray, vals: jnp.ndarray, x: jnp.ndarray):
    """cols/vals: [R, K]; x: [N] -> y [R]."""
    return jnp.sum(vals * x[cols], axis=1)


def spmv_ell_blocked_ref(
    cols: jnp.ndarray, vals: jnp.ndarray, x: jnp.ndarray, block_cols: int
):
    """Column-bucketed layout: cols/vals [R, C*K] with bucket ``j`` in
    columns [j*K, (j+1)*K) holding in-bucket indices into
    x[j*block_cols:(j+1)*block_cols]; x: [C*block_cols] -> y [R].

    Same arithmetic as the blocked Pallas kernel, expressed as one flat
    gather with the bucket base added back.
    """
    C = x.shape[0] // int(block_cols)
    K = cols.shape[1] // C
    base = jnp.repeat(
        jnp.arange(C, dtype=cols.dtype) * jnp.asarray(block_cols, cols.dtype),
        K,
    )
    return jnp.sum(vals * x[cols + base[None, :]], axis=1)


def spmv_ell_blocked_partial_ref(
    cols: jnp.ndarray, vals: jnp.ndarray, x: jnp.ndarray, y0: jnp.ndarray,
    bucket_lo: int, bucket_hi: int, block_cols: int, n_buckets: int,
):
    """Oracle for :func:`spmv_ell_blocked_partial`: accumulate buckets
    [lo, hi) of the full [R, C*K] layout into a carried ``y0``.  ``x``
    covers exactly that range ((hi-lo) * block_cols entries)."""
    lo, hi = int(bucket_lo), int(bucket_hi)
    if hi <= lo:
        return y0
    K = cols.shape[1] // int(n_buckets)
    sl_cols = cols[:, lo * K: hi * K]
    sl_vals = vals[:, lo * K: hi * K]
    base = jnp.repeat(
        jnp.arange(hi - lo, dtype=cols.dtype)
        * jnp.asarray(block_cols, cols.dtype),
        K,
    )
    return y0 + jnp.sum(sl_vals * x[sl_cols + base[None, :]], axis=1)
