"""Public ELL SpMV op: CSR->ELL conversion, padding, backend dispatch."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from .. import backend
from .ref import spmv_ell_ref
from .spmv_ell import DEFAULT_BLOCK_ROWS, spmv_ell


def csr_to_ell(
    indptr: np.ndarray, indices: np.ndarray, data: np.ndarray,
    n_rows: int, pad_col: int, block_rows: int = DEFAULT_BLOCK_ROWS,
) -> Tuple[np.ndarray, np.ndarray]:
    """Pad rows to uniform K and pad the row count to the block size.
    ``pad_col`` must point at an x entry that is always zero."""
    lens = np.diff(indptr)
    K = max(int(lens.max()) if len(lens) else 1, 1)
    R = int(n_rows + ((-n_rows) % min(block_rows, max(n_rows, 1))))
    cols = np.full((R, K), pad_col, dtype=np.int32)
    vals = np.zeros((R, K), dtype=np.float32)
    for i in range(n_rows):
        lo, hi = int(indptr[i]), int(indptr[i + 1])
        cols[i, : hi - lo] = indices[lo:hi]
        vals[i, : hi - lo] = data[lo:hi]
    return cols, vals


def spmv(cols: jnp.ndarray, vals: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    mode = backend()
    if mode == "reference":
        return spmv_ell_ref(cols, vals, x)
    R = cols.shape[0]
    br = DEFAULT_BLOCK_ROWS
    while R % br and br > 8:
        br //= 2
    if R % br:
        br = R
    return spmv_ell(
        cols, vals, x, block_rows=br,
        interpret=(mode == "pallas_interpret"),
    )
