"""Public ELL SpMV ops: CSR->ELL conversion, padding, backend dispatch."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from .. import backend
from .ref import (
    spmv_ell_blocked_partial_ref,
    spmv_ell_blocked_ref,
    spmv_ell_ref,
)
from .spmv_ell import (
    DEFAULT_BLOCK_COLS,
    DEFAULT_BLOCK_ROWS,
    spmv_ell,
    spmv_ell_blocked,
    spmv_ell_blocked_partial,
    spmv_ell_blocked_skip,
)


def csr_to_ell(
    indptr: np.ndarray, indices: np.ndarray, data: np.ndarray,
    n_rows: int, pad_col: int, block_rows: int = DEFAULT_BLOCK_ROWS,
) -> Tuple[np.ndarray, np.ndarray]:
    """Pad rows to uniform K and pad the row count to the block size.
    ``pad_col`` must point at an x entry that is always zero."""
    lens = np.diff(indptr)
    K = max(int(lens.max()) if len(lens) else 1, 1)
    R = int(n_rows + ((-n_rows) % min(block_rows, max(n_rows, 1))))
    cols = np.full((R, K), pad_col, dtype=np.int32)
    vals = np.zeros((R, K), dtype=np.float32)
    for i in range(n_rows):
        lo, hi = int(indptr[i]), int(indptr[i + 1])
        cols[i, : hi - lo] = indices[lo:hi]
        vals[i, : hi - lo] = data[lo:hi]
    return cols, vals


def spmv(cols: jnp.ndarray, vals: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Flat ELL SpMV: whole x VMEM-resident (kernel pads the row count)."""
    mode = backend()
    if mode == "reference":
        return spmv_ell_ref(cols, vals, x)
    return spmv_ell(cols, vals, x, interpret=(mode == "pallas_interpret"))


def spmv_blocked(
    cols: jnp.ndarray, vals: jnp.ndarray, x: jnp.ndarray,
    block_cols: int = DEFAULT_BLOCK_COLS,
) -> jnp.ndarray:
    """Column-blocked ELL SpMV over the bucketed [R, C*K] layout.

    ``x`` must be bucket-padded (length a multiple of ``block_cols``, as
    produced by the bucketed packing) — validated here so the reference
    and Pallas backends reject malformed input identically.
    """
    if x.shape[0] % block_cols:
        raise ValueError(
            f"x length {x.shape[0]} not a multiple of block_cols "
            f"{block_cols}: pack with partitioned_to_ell_blocked"
        )
    if cols.shape[1] % (x.shape[0] // block_cols):
        raise ValueError(
            f"cols width {cols.shape[1]} not divisible by the "
            f"{x.shape[0] // block_cols} x buckets"
        )
    mode = backend()
    if mode == "reference":
        return spmv_ell_blocked_ref(cols, vals, x, block_cols)
    return spmv_ell_blocked(
        cols, vals, x, block_cols=block_cols,
        interpret=(mode == "pallas_interpret"),
    )


def spmv_blocked_partial(
    cols: jnp.ndarray, vals: jnp.ndarray, x: jnp.ndarray, y0: jnp.ndarray,
    *,
    bucket_lo: int, bucket_hi: int, n_buckets: int,
    block_cols: int = DEFAULT_BLOCK_COLS,
) -> jnp.ndarray:
    """Blocked SpMV over buckets [lo, hi) accumulated into a carried ``y0``
    (the overlap schedule's per-phase entry point).  ``x`` holds only the
    range's slices: (hi - lo) * block_cols entries."""
    lo, hi = int(bucket_lo), int(bucket_hi)
    if not (0 <= lo <= hi <= n_buckets):
        raise ValueError(
            f"bucket range [{lo}, {hi}) outside [0, {n_buckets})"
        )
    if x.shape[0] != (hi - lo) * block_cols:
        raise ValueError(
            f"x length {x.shape[0]} != (hi-lo)*block_cols "
            f"{(hi - lo) * block_cols}"
        )
    if cols.shape[1] % n_buckets:
        raise ValueError(
            f"cols width {cols.shape[1]} not divisible by n_buckets "
            f"{n_buckets}"
        )
    mode = backend()
    if mode == "reference":
        return spmv_ell_blocked_partial_ref(
            cols, vals, x, y0, lo, hi, block_cols, n_buckets
        )
    return spmv_ell_blocked_partial(
        cols, vals, x, y0, bucket_lo=lo, bucket_hi=hi, n_buckets=n_buckets,
        block_cols=block_cols, interpret=(mode == "pallas_interpret"),
    )


def spmv_blocked_skip(
    cols: jnp.ndarray, vals: jnp.ndarray, x: jnp.ndarray,
    bucket_lists: jnp.ndarray, bucket_counts: jnp.ndarray,
    *,
    n_buckets: int, block_cols: int = DEFAULT_BLOCK_COLS,
    bucket_base: int = 0, y0: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Bucket-skipping blocked SpMV (per-row-block bucket lists, scalar
    prefetch).  ``x`` covers buckets [base, base + len(x)/block_cols).

    The reference backend exploits the packing invariant that unlisted
    buckets are all-zero (``row_block_bucket_map`` lists every bucket with
    a nonzero entry), so the dense partial sum over the covered window is
    the same value — keeping the CPU path one flat gather.
    """
    if x.shape[0] % block_cols:
        raise ValueError(
            f"x length {x.shape[0]} not a multiple of block_cols "
            f"{block_cols}"
        )
    if cols.shape[1] % n_buckets:
        raise ValueError(
            f"cols width {cols.shape[1]} not divisible by n_buckets "
            f"{n_buckets}"
        )
    mode = backend()
    if mode == "reference":
        lo = int(bucket_base)
        hi = lo + x.shape[0] // int(block_cols)
        y0r = y0 if y0 is not None else jnp.zeros(cols.shape[0], vals.dtype)
        return spmv_ell_blocked_partial_ref(
            cols, vals, x, y0r, lo, hi, block_cols, n_buckets
        )
    return spmv_ell_blocked_skip(
        cols, vals, x, bucket_lists, bucket_counts, n_buckets=n_buckets,
        block_cols=block_cols, bucket_base=bucket_base, y0=y0,
        interpret=(mode == "pallas_interpret"),
    )
