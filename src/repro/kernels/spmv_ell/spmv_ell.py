"""Local SpMV in ELL (padded-CSR) form as a Pallas TPU kernel.

This is the per-device compute of the paper's workload: after the halo
exchange delivers ghost values, each device multiplies its local sparse
block.  CSR's ragged rows are hostile to the VPU's lane layout, so rows are
padded to a uniform K nonzeros (ELL): ``cols``/``vals`` are [R, K] with
padding entries pointing at a zero slot.  The x vector lives fully in VMEM
(per-device local + ghost vectors are small: <= a few hundred KB), rows are
tiled over the grid, and the inner product is a VMEM dynamic gather +
multiply + row reduction.

For matrices whose x exceeds VMEM the production path is a column-blocked
variant (same kernel, x BlockSpec column-tiled, accumulating over a second
grid dim) — the AMG levels used here never need it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from ...compat import pallas_tpu_compiler_params

DEFAULT_BLOCK_ROWS = 256


def _spmv_kernel(cols_ref, vals_ref, x_ref, y_ref):
    cols = cols_ref[...]          # [BR, K] int32
    vals = vals_ref[...]          # [BR, K]
    x = x_ref[...]                # [N, 1]
    gathered = x[cols, 0]         # [BR, K] VMEM dynamic gather
    y_ref[...] = jnp.sum(vals * gathered, axis=1, keepdims=True)


def spmv_ell(
    cols: jnp.ndarray,   # [R, K] int32 (padding -> index of a zero x entry)
    vals: jnp.ndarray,   # [R, K]
    x: jnp.ndarray,      # [N]  (local values ++ ghost values ++ one zero pad)
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jnp.ndarray:
    R, K = cols.shape
    N = x.shape[0]
    br = min(block_rows, R)
    assert R % br == 0, (R, br)
    return pl.pallas_call(
        _spmv_kernel,
        grid=(R // br,),
        in_specs=[
            pl.BlockSpec((br, K), lambda i: (i, 0)),
            pl.BlockSpec((br, K), lambda i: (i, 0)),
            pl.BlockSpec((N, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, 1), vals.dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(cols, vals, x[:, None])[:, 0]
