"""Local SpMV in ELL (padded-CSR) form as Pallas TPU kernels.

This is the per-device compute of the paper's workload: after the halo
exchange delivers ghost values, each device multiplies its local sparse
block.  CSR's ragged rows are hostile to the VPU's lane layout, so rows are
padded to a uniform K nonzeros (ELL): ``cols``/``vals`` are [R, K] with
padding entries pointing at a zero slot.  Two execution paths:

* :func:`spmv_ell` — the flat kernel: the whole x vector lives in VMEM,
  rows are tiled over a 1-D grid, and the inner product is a VMEM dynamic
  gather + multiply + row reduction.  Right whenever the per-device local +
  ghost vector fits comfortably in VMEM (coarse AMG levels, small blocks).

* :func:`spmv_ell_blocked` — the production path for levels whose x exceeds
  VMEM (paper-scale fine levels): x is column-tiled over a second grid
  dimension, each grid step gathers only its ``block_cols``-wide x slice,
  and the row block's output accumulates across the column steps (the
  second grid dim is ``arbitrary``/sequential, the row dim stays parallel).
  The matching column-bucketed packing lives in
  ``repro.sparse.device.partitioned_to_ell_blocked``: each row's nonzeros
  are reordered into per-column-block buckets (in-bucket column indices),
  so ``cols``/``vals`` are [R, C*K] with bucket ``j`` occupying columns
  [j*K, (j+1)*K) and referencing only x[j*bc:(j+1)*bc).

* :func:`spmv_ell_blocked_partial` — the blocked kernel restricted to a
  bucket range [lo, hi), accumulating into a *carried* output.  This is
  the overlap building block: the distributed SpMV runs the local buckets
  while the halo exchange is in flight, then consumes the ghost buckets
  from the carried partial result (``repro.sparse.device.
  make_distributed_spmv(..., overlap=True)``).

* :func:`spmv_ell_blocked_skip` — the blocked kernel driven by per-row-
  block bucket *lists* via scalar prefetch: grid step (i, j) visits bucket
  ``bucket_lists[i, j]`` and steps past ``bucket_counts[i]`` are masked,
  so banded operators stream only the buckets a row block actually
  touches instead of every bucket.  Shares the carried-output convention
  with the partial kernel so the overlap schedule can use either per
  phase.

Row counts need not divide ``block_rows``: the trailing row block is padded
(col 0 / val 0 — the product is exactly zero) and the padding rows are
sliced off the output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from ...compat import pallas_tpu_compiler_params

DEFAULT_BLOCK_ROWS = 256
DEFAULT_BLOCK_COLS = 512


def _pad_rows(cols: jnp.ndarray, vals: jnp.ndarray, block_rows: int):
    """Pad the trailing row block; padding rows gather x[0] * 0.0 == 0."""
    R = cols.shape[0]
    br = min(block_rows, R)
    pad = (-R) % br
    if pad:
        cols = jnp.concatenate(
            [cols, jnp.zeros((pad, cols.shape[1]), cols.dtype)]
        )
        vals = jnp.concatenate(
            [vals, jnp.zeros((pad, vals.shape[1]), vals.dtype)]
        )
    return cols, vals, br


def _spmv_kernel(cols_ref, vals_ref, x_ref, y_ref):
    cols = cols_ref[...]          # [BR, K] int32
    vals = vals_ref[...]          # [BR, K]
    x = x_ref[...]                # [N, 1]
    gathered = x[cols, 0]         # [BR, K] VMEM dynamic gather
    y_ref[...] = jnp.sum(vals * gathered, axis=1, keepdims=True)


def spmv_ell(
    cols: jnp.ndarray,   # [R, K] int32 (padding -> index of a zero x entry)
    vals: jnp.ndarray,   # [R, K]
    x: jnp.ndarray,      # [N]  (local values ++ ghost values ++ one zero pad)
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jnp.ndarray:
    R = cols.shape[0]
    N = x.shape[0]
    cols, vals, br = _pad_rows(cols, vals, block_rows)
    Rp, K = cols.shape
    return pl.pallas_call(
        _spmv_kernel,
        grid=(Rp // br,),
        in_specs=[
            pl.BlockSpec((br, K), lambda i: (i, 0)),
            pl.BlockSpec((br, K), lambda i: (i, 0)),
            pl.BlockSpec((N, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, 1), vals.dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(cols, vals, x[:, None])[:R, 0]


def _spmv_blocked_kernel(cols_ref, vals_ref, x_ref, y_ref):
    j = pl.program_id(1)
    cols = cols_ref[...]          # [BR, K] in-bucket indices (< block_cols)
    vals = vals_ref[...]          # [BR, K]
    x = x_ref[...]                # [BC, 1] — only this bucket's x slice
    partial = jnp.sum(vals * x[cols, 0], axis=1, keepdims=True)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = partial

    @pl.when(j > 0)
    def _accumulate():
        y_ref[...] = y_ref[...] + partial


def spmv_ell_blocked(
    cols: jnp.ndarray,   # [R, C*K] int32 in-bucket indices (padding -> 0)
    vals: jnp.ndarray,   # [R, C*K]     (padding -> 0.0)
    x: jnp.ndarray,      # [C * block_cols]
    *,
    block_cols: int,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jnp.ndarray:
    """Column-blocked ELL SpMV: y[i] = sum_j sum_k vals[i,j*K+k] *
    x[j*bc + cols[i,j*K+k]].

    Grid is (row blocks, column buckets); the x BlockSpec is column-tiled so
    a grid step only holds one ``block_cols`` slice of x in VMEM, and the
    output row block accumulates over the sequential second grid dim.
    VMEM residency is therefore independent of ``len(x)`` — this is the
    paper-scale-fine-level path.
    """
    R = cols.shape[0]
    bc = int(block_cols)
    assert x.shape[0] % bc == 0, (x.shape, bc)
    C = x.shape[0] // bc
    assert cols.shape[1] % C == 0, (cols.shape, C)
    K = cols.shape[1] // C
    cols, vals, br = _pad_rows(cols, vals, block_rows)
    Rp = cols.shape[0]
    return pl.pallas_call(
        _spmv_blocked_kernel,
        grid=(Rp // br, C),
        in_specs=[
            pl.BlockSpec((br, K), lambda i, j: (i, j)),
            pl.BlockSpec((br, K), lambda i, j: (i, j)),
            pl.BlockSpec((bc, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((br, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, 1), vals.dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(cols, vals, x[:, None])[:R, 0]


def _pad_vec(y: jnp.ndarray, n: int) -> jnp.ndarray:
    if y.shape[0] == n:
        return y
    return jnp.concatenate([y, jnp.zeros((n - y.shape[0],), y.dtype)])


def _spmv_blocked_partial_kernel(cols_ref, vals_ref, x_ref, y0_ref, y_ref):
    j = pl.program_id(1)
    cols = cols_ref[...]          # [BR, K] in-bucket indices (< block_cols)
    vals = vals_ref[...]          # [BR, K]
    x = x_ref[...]                # [BC, 1] — this bucket's x slice
    partial = jnp.sum(vals * x[cols, 0], axis=1, keepdims=True)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = y0_ref[...] + partial

    @pl.when(j > 0)
    def _accumulate():
        y_ref[...] = y_ref[...] + partial


def spmv_ell_blocked_partial(
    cols: jnp.ndarray,   # [R, C*K] full bucketed layout (all buckets)
    vals: jnp.ndarray,   # [R, C*K]
    x: jnp.ndarray,      # [(hi-lo) * block_cols] — ONLY the range's x slices
    y0: jnp.ndarray,     # [R] carried output, accumulated into
    *,
    bucket_lo: int,
    bucket_hi: int,
    n_buckets: int,
    block_cols: int,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jnp.ndarray:
    """Blocked SpMV over buckets [bucket_lo, bucket_hi), accumulating into a
    carried ``y0``: y = y0 + sum_{j in [lo,hi)} A_bucket_j @ x_bucket_j.

    This is the overlap building block: the distributed schedule runs the
    local-bucket range while the halo exchange is in flight, then a second
    call consumes the ghost-bucket range with the local partial as ``y0``.
    ``cols``/``vals`` stay the full [R, C*K] layout (the BlockSpec index map
    offsets into it); ``x`` covers exactly the requested range.
    """
    R = cols.shape[0]
    lo, hi = int(bucket_lo), int(bucket_hi)
    C = int(n_buckets)
    bc = int(block_cols)
    if not (0 <= lo <= hi <= C):
        raise ValueError(f"bucket range [{lo}, {hi}) outside [0, {C})")
    if hi == lo:
        return y0
    assert x.shape[0] == (hi - lo) * bc, (x.shape, hi - lo, bc)
    assert cols.shape[1] % C == 0, (cols.shape, C)
    K = cols.shape[1] // C
    cols, vals, br = _pad_rows(cols, vals, block_rows)
    Rp = cols.shape[0]
    y0p = _pad_vec(y0, Rp)
    return pl.pallas_call(
        _spmv_blocked_partial_kernel,
        grid=(Rp // br, hi - lo),
        in_specs=[
            pl.BlockSpec((br, K), lambda i, j: (i, j + lo)),
            pl.BlockSpec((br, K), lambda i, j: (i, j + lo)),
            pl.BlockSpec((bc, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((br, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, 1), vals.dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(cols, vals, x[:, None], y0p[:, None])[:R, 0]


def _spmv_blocked_skip_kernel(bl_ref, cnt_ref, cols_ref, vals_ref, x_ref,
                              y0_ref, y_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)
    cols = cols_ref[...]          # [BR, K] — bucket bl_ref[i, j]'s columns
    vals = vals_ref[...]          # [BR, K]
    x = x_ref[...]                # [BC, 1] — bucket bl_ref[i, j]'s x slice
    partial = jnp.sum(vals * x[cols, 0], axis=1, keepdims=True)
    # steps past the row block's live-bucket count revisit a padding entry
    # of the list; mask their contribution to exactly zero
    live = (j < cnt_ref[i]).astype(vals.dtype)
    contrib = live * partial

    @pl.when(j == 0)
    def _init():
        y_ref[...] = y0_ref[...] + contrib

    @pl.when(j > 0)
    def _accumulate():
        y_ref[...] = y_ref[...] + contrib


def spmv_ell_blocked_skip(
    cols: jnp.ndarray,           # [R, C*K] full bucketed layout
    vals: jnp.ndarray,           # [R, C*K]
    x: jnp.ndarray,              # [n_x_buckets * block_cols]
    bucket_lists: jnp.ndarray,   # [NRB, M] int32 absolute bucket ids
    bucket_counts: jnp.ndarray,  # [NRB] int32 live entries per row block
    *,
    n_buckets: int,
    block_cols: int,
    bucket_base: int = 0,
    y0: jnp.ndarray | None = None,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jnp.ndarray:
    """Bucket-skipping blocked SpMV: grid step (i, j) visits bucket
    ``bucket_lists[i, j]`` of row block ``i`` (scalar-prefetched, so the
    BlockSpec index maps are data-dependent); steps j >= bucket_counts[i]
    are masked to zero contribution.  Banded operators whose row blocks
    touch few buckets stream only those, instead of every bucket.

    ``x`` covers buckets [bucket_base, bucket_base + len(x)/block_cols);
    every listed (and padding) bucket id must fall in that window.  With
    ``y0`` the result accumulates into a carried output, so the kernel
    serves both the fused path (base 0, full x) and either phase of the
    overlap schedule (local range, then ghost range carrying y).
    """
    R = cols.shape[0]
    C = int(n_buckets)
    bc = int(block_cols)
    base = int(bucket_base)
    assert cols.shape[1] % C == 0, (cols.shape, C)
    K = cols.shape[1] // C
    cols, vals, br = _pad_rows(cols, vals, block_rows)
    Rp = cols.shape[0]
    nrb = Rp // br
    assert bucket_lists.shape[0] == nrb, (bucket_lists.shape, nrb, br)
    M = bucket_lists.shape[1]
    y0p = (jnp.zeros((Rp,), vals.dtype) if y0 is None
           else _pad_vec(y0, Rp).astype(vals.dtype))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nrb, M),
        in_specs=[
            pl.BlockSpec((br, K), lambda i, j, bl, cnt: (i, bl[i, j])),
            pl.BlockSpec((br, K), lambda i, j, bl, cnt: (i, bl[i, j])),
            pl.BlockSpec((bc, 1), lambda i, j, bl, cnt: (bl[i, j] - base, 0)),
            pl.BlockSpec((br, 1), lambda i, j, bl, cnt: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, 1), lambda i, j, bl, cnt: (i, 0)),
    )
    return pl.pallas_call(
        _spmv_blocked_skip_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Rp, 1), vals.dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(bucket_lists.astype(jnp.int32), bucket_counts.astype(jnp.int32),
      cols, vals, x[:, None], y0p[:, None])[:R, 0]
