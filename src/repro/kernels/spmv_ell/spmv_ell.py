"""Local SpMV in ELL (padded-CSR) form as Pallas TPU kernels.

This is the per-device compute of the paper's workload: after the halo
exchange delivers ghost values, each device multiplies its local sparse
block.  CSR's ragged rows are hostile to the VPU's lane layout, so rows are
padded to a uniform K nonzeros (ELL): ``cols``/``vals`` are [R, K] with
padding entries pointing at a zero slot.  Two execution paths:

* :func:`spmv_ell` — the flat kernel: the whole x vector lives in VMEM,
  rows are tiled over a 1-D grid, and the inner product is a VMEM dynamic
  gather + multiply + row reduction.  Right whenever the per-device local +
  ghost vector fits comfortably in VMEM (coarse AMG levels, small blocks).

* :func:`spmv_ell_blocked` — the production path for levels whose x exceeds
  VMEM (paper-scale fine levels): x is column-tiled over a second grid
  dimension, each grid step gathers only its ``block_cols``-wide x slice,
  and the row block's output accumulates across the column steps (the
  second grid dim is ``arbitrary``/sequential, the row dim stays parallel).
  The matching column-bucketed packing lives in
  ``repro.sparse.device.partitioned_to_ell_blocked``: each row's nonzeros
  are reordered into per-column-block buckets (in-bucket column indices),
  so ``cols``/``vals`` are [R, C*K] with bucket ``j`` occupying columns
  [j*K, (j+1)*K) and referencing only x[j*bc:(j+1)*bc).

Row counts need not divide ``block_rows``: the trailing row block is padded
(col 0 / val 0 — the product is exactly zero) and the padding rows are
sliced off the output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from ...compat import pallas_tpu_compiler_params

DEFAULT_BLOCK_ROWS = 256
DEFAULT_BLOCK_COLS = 512


def _pad_rows(cols: jnp.ndarray, vals: jnp.ndarray, block_rows: int):
    """Pad the trailing row block; padding rows gather x[0] * 0.0 == 0."""
    R = cols.shape[0]
    br = min(block_rows, R)
    pad = (-R) % br
    if pad:
        cols = jnp.concatenate(
            [cols, jnp.zeros((pad, cols.shape[1]), cols.dtype)]
        )
        vals = jnp.concatenate(
            [vals, jnp.zeros((pad, vals.shape[1]), vals.dtype)]
        )
    return cols, vals, br


def _spmv_kernel(cols_ref, vals_ref, x_ref, y_ref):
    cols = cols_ref[...]          # [BR, K] int32
    vals = vals_ref[...]          # [BR, K]
    x = x_ref[...]                # [N, 1]
    gathered = x[cols, 0]         # [BR, K] VMEM dynamic gather
    y_ref[...] = jnp.sum(vals * gathered, axis=1, keepdims=True)


def spmv_ell(
    cols: jnp.ndarray,   # [R, K] int32 (padding -> index of a zero x entry)
    vals: jnp.ndarray,   # [R, K]
    x: jnp.ndarray,      # [N]  (local values ++ ghost values ++ one zero pad)
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jnp.ndarray:
    R = cols.shape[0]
    N = x.shape[0]
    cols, vals, br = _pad_rows(cols, vals, block_rows)
    Rp, K = cols.shape
    return pl.pallas_call(
        _spmv_kernel,
        grid=(Rp // br,),
        in_specs=[
            pl.BlockSpec((br, K), lambda i: (i, 0)),
            pl.BlockSpec((br, K), lambda i: (i, 0)),
            pl.BlockSpec((N, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, 1), vals.dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(cols, vals, x[:, None])[:R, 0]


def _spmv_blocked_kernel(cols_ref, vals_ref, x_ref, y_ref):
    j = pl.program_id(1)
    cols = cols_ref[...]          # [BR, K] in-bucket indices (< block_cols)
    vals = vals_ref[...]          # [BR, K]
    x = x_ref[...]                # [BC, 1] — only this bucket's x slice
    partial = jnp.sum(vals * x[cols, 0], axis=1, keepdims=True)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = partial

    @pl.when(j > 0)
    def _accumulate():
        y_ref[...] = y_ref[...] + partial


def spmv_ell_blocked(
    cols: jnp.ndarray,   # [R, C*K] int32 in-bucket indices (padding -> 0)
    vals: jnp.ndarray,   # [R, C*K]     (padding -> 0.0)
    x: jnp.ndarray,      # [C * block_cols]
    *,
    block_cols: int,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jnp.ndarray:
    """Column-blocked ELL SpMV: y[i] = sum_j sum_k vals[i,j*K+k] *
    x[j*bc + cols[i,j*K+k]].

    Grid is (row blocks, column buckets); the x BlockSpec is column-tiled so
    a grid step only holds one ``block_cols`` slice of x in VMEM, and the
    output row block accumulates over the sequential second grid dim.
    VMEM residency is therefore independent of ``len(x)`` — this is the
    paper-scale-fine-level path.
    """
    R = cols.shape[0]
    bc = int(block_cols)
    assert x.shape[0] % bc == 0, (x.shape, bc)
    C = x.shape[0] // bc
    assert cols.shape[1] % C == 0, (cols.shape, C)
    K = cols.shape[1] // C
    cols, vals, br = _pad_rows(cols, vals, block_rows)
    Rp = cols.shape[0]
    return pl.pallas_call(
        _spmv_blocked_kernel,
        grid=(Rp // br, C),
        in_specs=[
            pl.BlockSpec((br, K), lambda i, j: (i, j)),
            pl.BlockSpec((br, K), lambda i, j: (i, j)),
            pl.BlockSpec((bc, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((br, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, 1), vals.dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(cols, vals, x[:, None])[:R, 0]
