from .ops import (
    csr_to_ell,
    spmv,
    spmv_blocked,
    spmv_blocked_partial,
    spmv_blocked_skip,
)
from .ref import (
    spmv_ell_blocked_partial_ref,
    spmv_ell_blocked_ref,
    spmv_ell_ref,
)
from .spmv_ell import DEFAULT_BLOCK_COLS, DEFAULT_BLOCK_ROWS

__all__ = [
    "csr_to_ell", "spmv", "spmv_blocked",
    "spmv_blocked_partial", "spmv_blocked_skip",
    "spmv_ell_ref", "spmv_ell_blocked_ref", "spmv_ell_blocked_partial_ref",
    "DEFAULT_BLOCK_COLS", "DEFAULT_BLOCK_ROWS",
]
