from .ops import csr_to_ell, spmv
from .ref import spmv_ell_ref

__all__ = ["csr_to_ell", "spmv", "spmv_ell_ref"]
