from .ops import attention
from .ref import attention_ref, attention_ref_naive

__all__ = ["attention", "attention_ref", "attention_ref_naive"]
