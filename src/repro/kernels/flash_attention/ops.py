"""Public attention op: backend dispatch + GQA flattening + padding."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .. import backend
from .flash_attention import DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q, flash_attention_bh
from .ref import attention_ref


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def attention(
    q: jnp.ndarray,   # [B, Hq, Tq, d]
    k: jnp.ndarray,   # [B, Hkv, Tk, d]
    v: jnp.ndarray,
    *,
    scale: float | None = None,
    causal: bool = True,
    window: int = 0,
    kv_len: int | None = None,
    q_offset: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
) -> jnp.ndarray:
    """GQA attention; dispatches to the Pallas kernel or the jnp oracle."""
    if backend() == "reference":
        return attention_ref(
            q, k, v, scale=scale, causal=causal, window=window,
            kv_len=kv_len, q_offset=q_offset,
        )
    B, Hq, Tq, d = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    scale = (d ** -0.5) if scale is None else scale
    kv_len = Tk if kv_len is None else kv_len
    group = Hq // Hkv

    bq = min(block_q, max(8, Tq))
    bk = min(block_k, max(8, Tk))
    qp = _pad_to(q, 2, bq)
    kp = _pad_to(k, 2, bk)
    vp = _pad_to(v, 2, bk)
    # broadcast kv heads across the query-head groups, flatten (B, Hq)
    kp = jnp.repeat(kp, group, axis=1)
    vp = jnp.repeat(vp, group, axis=1)
    qf = qp.reshape(B * Hq, qp.shape[2], d)
    kf = kp.reshape(B * Hq, kp.shape[2], d)
    vf = vp.reshape(B * Hq, vp.shape[2], d)
    out = flash_attention_bh(
        qf, kf, vf,
        scale=scale, causal=causal, window=window, kv_len=kv_len,
        q_offset=q_offset, block_q=bq, block_k=bk,
        interpret=(backend() == "pallas_interpret"),
    )
    return out.reshape(B, Hq, qp.shape[2], d)[:, :, :Tq]
