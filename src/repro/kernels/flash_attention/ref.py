"""Pure-jnp oracle for flash attention (GQA + causal + sliding window).

Two implementations:

``attention_ref_naive`` — materializes the full [Tq, Tk] score matrix;
ground truth for small-shape kernel tests.

``attention_ref`` — CHUNKED online-softmax (lax.scan over KV chunks): the
same dataflow as the Pallas kernel, O(Tq * chunk) transient memory.  This
is what model code lowers on the reference backend, so the dry-run's
memory analysis reflects flash-attention behavior rather than a naive
O(T^2) blow-up.  (XLA cost analysis counts a scan body once; the dry-run
adds the analytic attention-FLOP correction — see launch/roofline.py.)

``window`` may be a traced scalar (0 = full attention).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30
DEFAULT_REF_CHUNK = 512


def _mask(q_pos, k_pos, causal, window, kv_len):
    m = k_pos < kv_len
    if causal:
        m &= k_pos <= q_pos
    win = jnp.asarray(window, jnp.int32)
    m &= (k_pos > q_pos - win) | (win <= 0)
    return m


def attention_ref_naive(
    q: jnp.ndarray,   # [B, Hq, Tq, d]
    k: jnp.ndarray,   # [B, Hkv, Tk, d]
    v: jnp.ndarray,
    *,
    scale: float | None = None,
    causal: bool = True,
    window: int = 0,
    kv_len: int | None = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    B, Hq, Tq, d = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    scale = (d ** -0.5) if scale is None else scale
    kv_len = Tk if kv_len is None else kv_len
    group = Hq // Hkv
    # f32 floor, but wider inputs (f64 bit-match checks) keep their width
    cdt = jnp.promote_types(q.dtype, jnp.float32)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(cdt),
                   kk.astype(cdt)) * scale
    q_pos = q_offset + jnp.arange(Tq)[:, None]
    k_pos = jnp.arange(Tk)[None, :]
    mask = _mask(q_pos, k_pos, causal, window, kv_len)
    s = jnp.where(mask[None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.where(l == 0, 1.0, l)
    out = jnp.einsum("bhqk,bhkd->bhqd", p.astype(cdt), vv.astype(cdt))
    return out.astype(q.dtype)


def attention_ref(
    q: jnp.ndarray,   # [B, Hq, Tq, d]
    k: jnp.ndarray,   # [B, Hkv, Tk, d]
    v: jnp.ndarray,
    *,
    scale: float | None = None,
    causal: bool = True,
    window: int = 0,
    kv_len: int | None = None,
    q_offset: int = 0,
    chunk: int = DEFAULT_REF_CHUNK,
) -> jnp.ndarray:
    B, Hq, Tq, d = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    if Tk <= chunk:
        return attention_ref_naive(
            q, k, v, scale=scale, causal=causal, window=window,
            kv_len=kv_len, q_offset=q_offset,
        )
    scale = (d ** -0.5) if scale is None else scale
    kv_len = Tk if kv_len is None else kv_len
    group = Hq // Hkv

    pad = (-Tk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nc = k.shape[2] // chunk
    kc = jnp.moveaxis(
        k.reshape(B, Hkv, nc, chunk, d), 2, 0
    )  # [nc, B, Hkv, chunk, d]
    vc = jnp.moveaxis(v.reshape(B, Hkv, nc, chunk, d), 2, 0)

    cdt = jnp.promote_types(q.dtype, jnp.float32)
    qf = q.astype(cdt)
    q_pos = q_offset + jnp.arange(Tq)[:, None]

    def body(carry, inp):
        m_prev, l_prev, acc = carry
        kb, vb, ci = inp
        kb = jnp.repeat(kb, group, axis=1).astype(cdt)
        vb = jnp.repeat(vb, group, axis=1).astype(cdt)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kb) * scale
        k_pos = ci * chunk + jnp.arange(chunk)[None, :]
        mask = _mask(q_pos, k_pos, causal, window, kv_len)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = corr * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc = corr * acc + jnp.einsum("bhqk,bhkd->bhqd", p, vb)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hq, Tq, 1), NEG_INF, cdt)
    l0 = jnp.zeros((B, Hq, Tq, 1), cdt)
    a0 = jnp.zeros((B, Hq, Tq, d), cdt)
    # checkpoint the chunk body: backward recomputes the [Tq, chunk] scores
    # per chunk instead of saving them all (flash-attention's bwd strategy);
    # residuals shrink from O(Tq*Tk) to O(Tq*d) per chunk.
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, a0), (kc, vc, jnp.arange(nc))
    )
    out = acc / jnp.where(l == 0, 1.0, l)
    return out.astype(q.dtype)
