"""Blocked online-softmax attention (FlashAttention) as a Pallas TPU kernel.

Supports GQA (kv heads broadcast over query-head groups), causal masking,
and sliding-window attention (Mixtral/Gemma-3 local layers).

Tiling: grid = (batch*q_heads, num_q_blocks, num_kv_blocks); the KV-block
dimension is innermost and marked "arbitrary" so the (m, l, acc) online
softmax state lives in VMEM scratch across KV steps.  Q/K/V tiles are
MXU-aligned: block_q x head_dim and block_k x head_dim with head_dim padded
to a multiple of 128 by ops.py.  VMEM working set per step:
(block_q + 2*block_k) * d * 4B + acc (block_q * d * 4B) — ~0.4 MB at the
default 128/128/128 tiling, far under the ~16 MB VMEM budget, leaving room
for double-buffered pipelining of the K/V streams.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from ...compat import pallas_tpu_compiler_params

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref,      # [1, bq, d], [1, bk, d], [1, bk, d]
    o_ref,                    # [1, bq, d]
    m_scr, l_scr, acc_scr,    # VMEM scratch: [bq, 1], [bq, 1], [bq, d]
    *,
    scale: float,
    causal: bool,
    window: int,
    block_q: int,
    block_k: int,
    kv_len: int,
    q_offset: int,
    num_kv_blocks: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # [bq, d]
    k = k_ref[0].astype(jnp.float32)  # [bk, d]
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [bq, bk]

    # absolute positions (q_offset supports decode: query at position cache_len)
    q_pos = (
        q_offset
        + qi * block_q
        + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    )
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )
    mask = k_pos < kv_len  # padding mask
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                      # [bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                   # [bq, bk]
    correction = jnp.exp(m_prev - m_new)     # [bq, 1]
    l_scr[...] = correction * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = correction * acc_scr[...] + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[0] = (acc_scr[...] / safe_l).astype(o_ref.dtype)


def flash_attention_bh(
    q: jnp.ndarray,   # [BH, Tq, d]
    k: jnp.ndarray,   # [BH, Tk, d]
    v: jnp.ndarray,   # [BH, Tk, d]
    *,
    scale: float,
    causal: bool,
    window: int = 0,
    kv_len: int | None = None,
    q_offset: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jnp.ndarray:
    """Attention over flattened (batch*heads) with pre-padded shapes
    (ops.py guarantees Tq % block_q == 0, Tk % block_k == 0)."""
    BH, Tq, d = q.shape
    Tk = k.shape[1]
    kv_len = Tk if kv_len is None else kv_len
    nq = Tq // block_q
    nk = Tk // block_k

    kernel = functools.partial(
        _attn_kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        kv_len=kv_len,
        q_offset=q_offset,
        num_kv_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
