"""Pallas TPU kernels for the compute hot spots + backend selection.

Each kernel lives in ``kernels/<name>/`` with three files:

* ``<name>.py`` — the ``pl.pallas_call`` kernel with explicit BlockSpec VMEM
  tiling (TPU is the target; ``interpret=True`` validates on CPU),
* ``ops.py``   — the jit'd public wrapper (padding, dtype plumbing, vmap),
* ``ref.py``   — the pure-jnp oracle used by tests and by the CPU/dry-run
  path (Pallas TPU kernels cannot lower on the CPU backend, so model code
  calls ``ops.<fn>`` which dispatches on :func:`backend`).

Backends: ``reference`` (default on CPU; also what the 512-device dry-run
lowers, keeping HLO costs analyzable), ``pallas_interpret`` (kernel body
executed in Python — correctness tests), ``pallas`` (real TPU).
"""
from __future__ import annotations

import os
from contextlib import contextmanager

_BACKEND = os.environ.get("REPRO_KERNEL_BACKEND", "reference")
_VALID = ("reference", "pallas_interpret", "pallas")


def backend() -> str:
    return _BACKEND


def set_backend(name: str) -> None:
    global _BACKEND
    if name not in _VALID:
        raise ValueError(f"backend {name!r} not in {_VALID}")
    _BACKEND = name


@contextmanager
def use_backend(name: str):
    global _BACKEND
    old = _BACKEND
    set_backend(name)
    try:
        yield
    finally:
        _BACKEND = old


def interpret_mode() -> bool:
    return _BACKEND == "pallas_interpret"
