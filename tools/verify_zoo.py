"""Run ``repro.verify`` over the standard plan zoo on 8 virtual devices.

The CI static-analysis gate: every plan producer in the repo is exercised
with ``REPRO_VERIFY=1``, so each plan is verified on insertion into the
``PlanCache`` (structure + conservation + device plan), each bound
executor is jaxpr-audited against its DevicePlan, and the hierarchy-level
sweeps re-check partitions, ELL layouts, bucket maps and kernel budgets:

* ``DistributedHierarchy.setup`` — host lowering of the AMG smoke problem
  (solve halos, R/P transfer operators, flat + blocked kernels);
* ``DistributedHierarchy.setup_partitioned`` — the distributed setup,
  whose SpGEMM gather patterns ride through the same cache;
* ``repartition`` — the elastic rebuild onto a different device count;
* ``moe_plan_for`` — every MoE dispatch mode (a2a / hier / hier_dedup and
  the auto selector), plus the token-conservation check per plan;
* ``PlanCache.dense_collective`` — the dense plan zoo: every collective
  (allreduce / allgatherv / reduce_scatter) in every variant the
  geometry admits (ring / rd / hier), on both 8-device geometries
  (4 regions x 2 and 2 regions x 4), verified on insertion
  (conflict-free rounds + symbolic conservation) with each bound
  executor jaxpr-audited round-for-round against its schedule.

Exit 0 with a per-producer summary, or the first ``VerifyError``
propagates and fails the job with its rank/bucket diagnostic.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ["REPRO_VERIFY"] = "1"

import numpy as np  # noqa: E402


def main() -> int:
    import jax

    assert jax.device_count() == 8, jax.devices()
    from repro.amg import (
        DistributedHierarchy,
        build_hierarchy,
        diffusion_2d,
        partition_fine_matrix,
    )
    from repro.configs import reduced
    from repro.core import PlanCache
    from repro.models.moe import moe_plan_for
    from repro.verify import verify_hierarchy, verify_moe_dispatch

    summary = {}
    mesh = jax.make_mesh((8,), ("proc",))
    A = diffusion_2d(32, 32)

    # -- host lowering: solve halos + R/P operators ------------------------
    cache = PlanCache()
    dh = DistributedHierarchy.setup(
        build_hierarchy(A), mesh, procs_per_region=4, cache=cache
    )
    summary["setup"] = verify_hierarchy(dh)

    # -- blocked-kernel layouts (bucket maps + budgets) on the same zoo ----
    dh_blocked = DistributedHierarchy.setup(
        build_hierarchy(A), mesh, procs_per_region=4, cache=cache,
        spmv_variant="blocked", spmv_block_cols=64,
    )
    summary["setup_blocked"] = verify_hierarchy(dh_blocked)

    # -- distributed setup: SpGEMM gather patterns through the cache ------
    blocks, off = partition_fine_matrix(A, 8)
    dhp = DistributedHierarchy.setup_partitioned(
        blocks, off, mesh, procs_per_region=4, cache=cache
    )
    summary["setup_partitioned"] = verify_hierarchy(dhp)

    # -- elastic repartition onto a different device count -----------------
    from jax.sharding import Mesh

    mesh4 = Mesh(np.array(jax.devices()[:4]), ("proc",))
    dh4 = dh.repartition(mesh4, procs_per_region=2, reason="verify_zoo")
    summary["repartition"] = verify_hierarchy(dh4)

    # -- MoE dispatch: every mode + the auto selector ----------------------
    cfg = reduced("mixtral-8x7b")
    moe_mesh = jax.make_mesh((1, 8), ("data", "model"))
    tokens = 64
    moe_counts = {}
    for mode in ("a2a", "hier", "hier_dedup", "auto"):
        plan = moe_plan_for(cfg, moe_mesh, tokens, mode=mode, cache=cache)
        verify_moe_dispatch(plan, tokens)
        moe_counts[mode] = plan.mode
    summary["moe"] = moe_counts

    # -- dense collectives: every variant on both 8-device geometries ------
    from repro.core import DENSE_COLLECTIVES, Topology
    from repro.core.dense import dense_variants

    dense_counts = {}
    rng = np.random.default_rng(0)
    for ppr in (2, 4):
        topo = Topology(8, ppr)
        for coll in DENSE_COLLECTIVES:
            # uneven counts so conservation is checked on a ragged wire
            counts = rng.integers(3, 17, size=8)
            for variant in dense_variants(coll, topo) + ["auto"]:
                plan, sel = cache.dense_collective(coll, counts, topo,
                                                   variant=variant)
                cache.dense_executor(plan, mesh, "proc")  # jaxpr audit
                if variant == "auto":
                    dense_counts[f"{coll}@ppr{ppr}"] = sel.chosen
    summary["dense"] = dense_counts

    stats = cache.stats()
    print("verify_zoo: all plan producers verified")
    for producer, counts in summary.items():
        print(f"  {producer}: {counts}")
    print(
        "  cache: "
        + ", ".join(
            f"{ns}={d['entries']}" for ns, d in stats["namespaces"].items()
        )
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
