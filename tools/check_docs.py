"""Docs gate: relative-link/anchor check + runnable quickstart snippets.

Two checks over README.md and docs/*.md:

1. **Links** (``--links-only`` stops here): every relative markdown link
   must point at a file that exists in the checkout, and every
   ``#fragment`` must match a heading slug (GitHub slugger rules) in the
   target file.  External links (``http(s)://``, ``mailto:``) and links
   that resolve outside the repo (the CI badge's ``../../actions/...``)
   are skipped — this container has no network.
2. **Snippets**: the fenced ```python blocks of docs/ARCHITECTURE.md are
   concatenated top-to-bottom into one script (later snippets may build
   on earlier ones — the documented convention) and executed in a
   subprocess on 8 virtual devices.  A quickstart that drifts from the
   API fails CI instead of rotting.

    PYTHONPATH=src python tools/check_docs.py [--links-only]
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
import tempfile
import pathlib

REPO = pathlib.Path(__file__).resolve().parents[1]
DOC_FILES = [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]
SNIPPET_FILE = REPO / "docs" / "ARCHITECTURE.md"

# [text](target) — excluding images' leading "!" is unnecessary: image
# targets are files and should exist too
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```python\s*$(.*?)^```\s*$",
                      re.MULTILINE | re.DOTALL)


def heading_slugs(path: pathlib.Path) -> set:
    """GitHub-style slugs of every markdown heading in ``path``."""
    slugs = set()
    counts: dict = {}
    in_fence = False
    for line in path.read_text().splitlines():
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence or not line.startswith("#"):
            continue
        text = line.lstrip("#").strip()
        # strip markdown emphasis/code markers, then slugify
        text = re.sub(r"[*_`]", "", text)
        slug = re.sub(r"[^\w\- ]", "", text.lower()).strip()
        slug = re.sub(r" +", "-", slug)
        n = counts.get(slug, 0)
        counts[slug] = n + 1
        slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def check_links() -> list:
    errors = []
    for doc in DOC_FILES:
        if not doc.exists():
            errors.append(f"{doc}: missing doc file")
            continue
        in_fence = False
        for lineno, line in enumerate(doc.read_text().splitlines(), 1):
            if line.lstrip().startswith("```"):
                in_fence = not in_fence
            if in_fence:
                continue
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:")):
                    continue
                path_part, _, frag = target.partition("#")
                base = doc.parent / path_part if path_part else doc
                base = pathlib.Path(os.path.normpath(base))
                if REPO not in base.parents and base != REPO:
                    continue   # escapes the checkout (CI badge etc.)
                if not base.exists():
                    errors.append(f"{doc.relative_to(REPO)}:{lineno}: "
                                  f"broken link -> {target}")
                    continue
                if frag and base.suffix == ".md":
                    if frag.lower() not in heading_slugs(base):
                        errors.append(
                            f"{doc.relative_to(REPO)}:{lineno}: "
                            f"broken anchor -> {target}")
    return errors


def run_snippets() -> int:
    blocks = FENCE_RE.findall(SNIPPET_FILE.read_text())
    if not blocks:
        print(f"check_docs: no python snippets in {SNIPPET_FILE}",
              file=sys.stderr)
        return 1
    script = "\n\n".join(b.strip("\n") for b in blocks) + "\n"
    env = dict(os.environ)
    env.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    with tempfile.NamedTemporaryFile(
            "w", suffix=".py", delete=False) as f:
        f.write(script)
        tmp = f.name
    try:
        print(f"check_docs: executing {len(blocks)} snippet(s) from "
              f"{SNIPPET_FILE.relative_to(REPO)}")
        proc = subprocess.run([sys.executable, tmp], env=env, timeout=600)
        return proc.returncode
    finally:
        os.unlink(tmp)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--links-only", action="store_true",
                    help="skip executing the ARCHITECTURE.md snippets")
    args = ap.parse_args(argv)

    errors = check_links()
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    n_links = sum(1 for _ in DOC_FILES)
    print(f"check_docs: links OK across {n_links} file(s)"
          if not errors else f"check_docs: {len(errors)} link error(s)")
    if errors:
        return 1
    if args.links_only:
        return 0
    rc = run_snippets()
    print("check_docs: snippets OK" if rc == 0
          else f"check_docs: snippet run failed (exit {rc})")
    return rc


if __name__ == "__main__":
    sys.exit(main())
