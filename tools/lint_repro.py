"""Repo-specific AST lint — bug classes this codebase has actually hit.

Four rules, each guarding an invariant the generic linters don't know
about:

* **R1 mutable-dataclass-default** — a dataclass field whose default is a
  mutable display (``[]``, ``{}``, ``set()``) or a non-whitelisted call is
  shared across every instance (the PR 7 ``StragglerConfig`` bug class:
  one engine's straggler history mutated another's config).  Use
  ``dataclasses.field(default_factory=...)``.
* **R2 unsorted-hash-iteration** — inside any function that feeds a hash
  (``hashlib.*`` / ``pattern_fingerprint``), iterating a dict/set view
  without ``sorted(...)`` makes the digest depend on insertion/hash order
  and silently breaks cross-process fingerprint determinism.
* **R3 tracer-missing-pure-exchange** — every ``*.record_plan(...)`` call
  must pass ``pure_exchange=`` explicitly: the default (True) feeds the
  sample into the NNLS rate fit, so an unlabeled impure timing (exchange
  fused with compute) silently skews every fitted machine rate.
* **R4 raw-perf-counter** — library code under ``src/repro/`` must not
  call ``time.perf_counter()`` directly (``repro.obs`` and
  ``repro.profile`` excepted: they *define* the timing layer).  Use
  ``repro.obs.now()`` or a span so wall time is observable through one
  clock and the telemetry layer sees every timing site.

Run as ``python -m tools.lint_repro [roots...]`` (defaults to ``src``
``benchmarks`` ``tools``); exits 1 if anything is flagged.  Findings
print as ``path:line: RULE-ID message`` so CI logs are clickable.
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path
from typing import Iterator, List, Tuple

Finding = Tuple[Path, int, str, str]   # (file, line, rule, message)

DEFAULT_ROOTS = ("src", "benchmarks", "tools")

#: calls that are safe as dataclass defaults: dataclasses.field itself and
#: constructors of immutable values
_SAFE_DEFAULT_CALLS = frozenset({
    "field", "dataclasses.field",
    "float", "int", "str", "bool", "bytes", "complex",
    "tuple", "frozenset",
})

#: modules allowed to call record_plan without the keyword (the definition
#: module itself: its internal forwarding sets the semantics)
_R3_EXEMPT = ("repro/profile/trace.py",)

#: R4 applies only inside the library; these subpackages define the
#: timing/telemetry layer and so hold the blessed perf_counter sites
_R4_SCOPE = "src/repro/"
_R4_EXEMPT_PARTS = ("repro/obs/", "repro/profile/")


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of a call target ('dataclasses.field')."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""


def _is_dataclass_decorated(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        if _dotted(target).split(".")[-1] == "dataclass":
            return True
    return False


def _mutable_default(value: ast.AST) -> str:
    """Why a default expression is mutable-shared, or '' if it is fine."""
    if isinstance(value, (ast.List, ast.Dict, ast.Set)):
        return f"literal {type(value).__name__.lower()} display"
    if isinstance(value, ast.Call):
        name = _dotted(value.func)
        if name in _SAFE_DEFAULT_CALLS or \
                name.split(".")[-1] in ("field",):
            return ""
        return f"call to {name or '<expr>'}()"
    return ""


def _check_dataclass_defaults(tree: ast.Module, path: Path,
                              out: List[Finding]) -> None:
    for node in ast.walk(tree):
        if not (isinstance(node, ast.ClassDef) and
                _is_dataclass_decorated(node)):
            continue
        for stmt in node.body:
            if not (isinstance(stmt, ast.AnnAssign) and
                    stmt.value is not None):
                continue
            why = _mutable_default(stmt.value)
            if why:
                field_name = getattr(stmt.target, "id", "<field>")
                out.append((
                    path, stmt.lineno, "R1-mutable-dataclass-default",
                    f"dataclass {node.name}.{field_name} default is a "
                    f"{why}, shared across instances — use "
                    "dataclasses.field(default_factory=...)",
                ))


def _feeds_hash(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            name = _dotted(node.func)
            if name.startswith("hashlib.") or \
                    name.split(".")[-1] in ("blake2b", "sha256", "md5",
                                            "pattern_fingerprint"):
                return True
    return False


def _iter_targets(fn: ast.AST) -> Iterator[ast.expr]:
    """Expressions iterated by for-loops and comprehensions in ``fn``."""
    for node in ast.walk(fn):
        if isinstance(node, (ast.For, ast.AsyncFor)):
            yield node.iter
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            for gen in node.generators:
                yield gen.iter


def _check_hash_iteration(tree: ast.Module, path: Path,
                          out: List[Finding]) -> None:
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not _feeds_hash(fn):
            continue
        for it in _iter_targets(fn):
            # unwrapped dict/set views: x.items()/.keys()/.values(), set(x)
            unordered = ""
            if isinstance(it, ast.Call):
                name = _dotted(it.func)
                if name.endswith((".items", ".keys", ".values")):
                    unordered = name.split(".")[-1] + "()"
                elif name == "set":
                    unordered = "set()"
            elif isinstance(it, ast.Set):
                unordered = "set display"
            if unordered:
                out.append((
                    path, it.lineno, "R2-unsorted-hash-iteration",
                    f"iterating {unordered} inside hash-feeding function "
                    f"{fn.name}() — wrap in sorted(...) or the digest "
                    "depends on insertion order",
                ))


def _check_record_plan(tree: ast.Module, path: Path,
                       out: List[Finding]) -> None:
    if str(path).replace("\\", "/").endswith(_R3_EXEMPT):
        return
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call) and
                isinstance(node.func, ast.Attribute) and
                node.func.attr == "record_plan"):
            continue
        if not any(kw.arg == "pure_exchange" for kw in node.keywords):
            out.append((
                path, node.lineno, "R3-tracer-missing-pure-exchange",
                "record_plan() without an explicit pure_exchange= — the "
                "silent default (True) feeds this sample into the machine-"
                "rate fit; state whether the timing is a pure exchange",
            ))


def _check_perf_counter(tree: ast.Module, path: Path,
                        out: List[Finding]) -> None:
    posix = str(path).replace("\\", "/")
    if _R4_SCOPE not in posix:
        return
    if any(part in posix for part in _R4_EXEMPT_PARTS):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _dotted(node.func)
        if name == "perf_counter" or name.endswith(".perf_counter"):
            out.append((
                path, node.lineno, "R4-raw-perf-counter",
                "direct time.perf_counter() in library code — use "
                "repro.obs.now() (or wrap the region in an obs span) so "
                "all wall-clock reads go through the telemetry layer",
            ))


def lint_file(path: Path) -> List[Finding]:
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as e:  # pragma: no cover - repo code always parses
        return [(path, e.lineno or 0, "R0-syntax-error", str(e))]
    out: List[Finding] = []
    _check_dataclass_defaults(tree, path, out)
    _check_hash_iteration(tree, path, out)
    _check_record_plan(tree, path, out)
    _check_perf_counter(tree, path, out)
    return out


def lint_paths(roots) -> List[Finding]:
    findings: List[Finding] = []
    for root in roots:
        root = Path(root)
        files = sorted(root.rglob("*.py")) if root.is_dir() else [root]
        for f in files:
            findings.extend(lint_file(f))
    return findings


def main(argv=None) -> int:
    roots = (argv if argv else sys.argv[1:]) or list(DEFAULT_ROOTS)
    findings = lint_paths(roots)
    for path, line, rule, msg in findings:
        print(f"{path}:{line}: {rule} {msg}")
    n_files = sum(1 for root in roots for _ in
                  (Path(root).rglob("*.py") if Path(root).is_dir()
                   else [Path(root)]))
    if findings:
        print(f"lint_repro: {len(findings)} finding(s) in {n_files} files")
        return 1
    print(f"lint_repro: clean ({n_files} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
